//! Approximate-DRAM fault-injection layer (EDEN / SparkXD-style error
//! models).
//!
//! The repo's channel was perfect until this module: nothing ever
//! flipped a bit, so the paper's quality-loss axis on *error resilient*
//! applications was unreproducible. EDEN (arXiv:1910.05340) models
//! voltage/latency-scaled DRAM as a bit-error-rate that rises roughly
//! one decade per ~50 mV below nominal, weighted toward 1→0 flips
//! (charge loss in true cells); SparkXD (arXiv:2103.00421) splits
//! traffic by criticality so only error-resilient accesses ride the
//! scaled (faulty) path.
//!
//! Both ideas land here:
//!
//! * [`FaultModel`] — the deterministic, seed-driven corruption hook
//!   the one shared drive loop ([`crate::encoding::lane::drive_batches`])
//!   applies to the wire **between** `transmit_batch` and
//!   `decode_batch`. Energy accounting is untouched by construction
//!   (the transfer already happened); only what the receiver *senses*
//!   changes.
//! * [`FaultSpec`] — the serializable knob bag every ingestion boundary
//!   (CLI `--faults`, run/sweep TOML, `Session::builder().faults(..)`)
//!   parses and validates, mirroring the `CodecSpec` contract: a bad
//!   spec is an error at the boundary, never a silent fallback.
//! * Criticality split: the drive loop only corrupts words whose
//!   per-access flag marks them error-resilient —
//!   [`TrafficClass::Critical`](crate::session::TrafficClass) streams
//!   bypass injection entirely, SparkXD-style. (The guarantee is
//!   per-access *injection*; in a mixed per-word stream, corruption of
//!   an approximate transfer can propagate through a table-based
//!   codec's shared mirror state into later words — see
//!   `encoding::lane` for the exact scope.)
//!
//! Determinism contract: a model's flip sequence is a pure function of
//! `(spec seed, shard, chip, words seen so far)`. There is no wall-clock
//! or OS entropy anywhere, so a fixed-seed run is byte-for-byte
//! reproducible at any channel count, and `FaultSpec::perfect()` is
//! pinned bit-identical to the historical no-fault path by property
//! tests (`rust/tests/faults.rs`).

pub mod model;
pub mod profile;

pub use model::{FaultModel, PerLaneBer, PerfectChannel, UniformBer};
pub use profile::{FaultProfile, MramBin, MramProfile};

/// Per-stream fault-injection statistics, merged across chips and
/// shards exactly like [`EncodeStats`](crate::encoding::EncodeStats).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Wire data bits flipped by the model.
    pub injected_bits: u64,
    /// Transfers with at least one injected flip.
    pub injected_words: u64,
    /// End-to-end error bits: Σ hamming(original word, decoded word).
    /// Includes codec approximation *and* fault propagation, so with a
    /// perfect channel this is the pure approximation error.
    pub observed_error_bits: u64,
    /// Data bits a correcting codec's decoder repaired (SECDED sideband
    /// syndrome hits, in-band Hamming repairs, ECC-wrapper repairs).
    /// 0 for every non-correcting scheme.
    pub corrected_bits: u64,
    /// Error bits a correcting codec flagged but could not repair
    /// (double-bit detections and the like). Detection-only schemes
    /// (PARITY) count everything they see here.
    pub detected_bits: u64,
    /// End-to-end error bits inside the codec's resilience mask while
    /// the fault model was active — the damage that survived
    /// correction. Perfect-channel runs leave this 0 by construction
    /// (codec approximation alone is not "residual" error), so
    /// `residual == 0` under faults is the signature of full recovery.
    pub residual_error_bits: u64,
    /// Words driven (denominator for the rates below).
    pub words: u64,
}

impl FaultStats {
    /// Merge another stream's stats (per-chip / per-shard aggregation).
    pub fn merge(&mut self, o: &FaultStats) {
        self.injected_bits += o.injected_bits;
        self.injected_words += o.injected_words;
        self.observed_error_bits += o.observed_error_bits;
        self.corrected_bits += o.corrected_bits;
        self.detected_bits += o.detected_bits;
        self.residual_error_bits += o.residual_error_bits;
        self.words += o.words;
    }

    /// Injected flips per transferred data bit (the measured BER).
    pub fn injected_ber(&self) -> f64 {
        if self.words == 0 {
            0.0
        } else {
            self.injected_bits as f64 / (self.words as f64 * 64.0)
        }
    }

    /// End-to-end error bits per data bit (the quality-delta rate).
    pub fn observed_error_rate(&self) -> f64 {
        if self.words == 0 {
            0.0
        } else {
            self.observed_error_bits as f64 / (self.words as f64 * 64.0)
        }
    }

    /// Uncorrected fault damage per data bit (the post-ECC BER).
    pub fn residual_error_rate(&self) -> f64 {
        if self.words == 0 {
            0.0
        } else {
            self.residual_error_bits as f64 / (self.words as f64 * 64.0)
        }
    }
}

/// Which error model a [`FaultSpec`] builds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// No corruption — the historical behaviour, and the default.
    Perfect,
    /// Uniform BER across all lanes with 1→0/0→1 asymmetry.
    Uniform {
        /// Overall bit-error rate in [0, 1].
        ber: f64,
        /// Fraction of flips that are 1→0 on balanced data, in [0, 1]
        /// (charge-loss asymmetry; EDEN's default here is 0.75).
        one_to_zero_fraction: f64,
    },
    /// EDEN-style voltage-binned profile: the supply-voltage knob maps
    /// to a per-lane BER through [`FaultProfile`].
    Voltage {
        /// DRAM supply voltage in millivolts
        /// ([`FaultProfile::MIN_MV`]..=[`FaultProfile::NOMINAL_MV`]).
        millivolts: u32,
    },
    /// Approximate-MRAM reliability bin (STT-MRAM read-disturb /
    /// retention profile, [`MramBin`]) — the second memory technology.
    /// Opposite polarity to DRAM: errors are weighted toward 0→1 flips
    /// (read disturb sets the free layer), with mild linear lane
    /// variation instead of DRAM's long weak-column tail.
    Mram {
        /// Which reliability bin the cell array is operated in.
        bin: MramBin,
    },
}

/// A validated, serializable fault-model description: the fault-layer
/// analogue of [`CodecSpec`](crate::encoding::CodecSpec).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    /// Base seed; each (shard, chip) lane derives a decorrelated
    /// sub-stream from it.
    pub seed: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec::perfect()
    }
}

impl FaultSpec {
    /// Default injection seed (any fixed value works; this one is just
    /// recognizable in reports).
    pub const DEFAULT_SEED: u64 = 0x5EED_FA17;

    /// The charge-loss asymmetry used when a spec doesn't pick its own:
    /// three of four flips discharge a stored 1.
    pub const DEFAULT_ONE_TO_ZERO_FRACTION: f64 = 0.75;

    /// No corruption (the historical behaviour).
    pub fn perfect() -> FaultSpec {
        FaultSpec {
            kind: FaultKind::Perfect,
            seed: Self::DEFAULT_SEED,
        }
    }

    /// Uniform BER with the default 1→0 bias.
    pub fn uniform(ber: f64) -> FaultSpec {
        FaultSpec {
            kind: FaultKind::Uniform {
                ber,
                one_to_zero_fraction: Self::DEFAULT_ONE_TO_ZERO_FRACTION,
            },
            seed: Self::DEFAULT_SEED,
        }
    }

    /// EDEN-style voltage-scaled profile at `millivolts`.
    pub fn voltage(millivolts: u32) -> FaultSpec {
        FaultSpec {
            kind: FaultKind::Voltage { millivolts },
            seed: Self::DEFAULT_SEED,
        }
    }

    /// Approximate-MRAM profile in reliability bin `bin`.
    pub fn mram(bin: MramBin) -> FaultSpec {
        FaultSpec {
            kind: FaultKind::Mram { bin },
            seed: Self::DEFAULT_SEED,
        }
    }

    /// Same spec with an explicit base seed.
    pub fn with_seed(mut self, seed: u64) -> FaultSpec {
        self.seed = seed;
        self
    }

    /// Whether this spec can never flip a bit (lets every layer keep
    /// the historical fast path).
    pub fn is_perfect(&self) -> bool {
        match self.kind {
            FaultKind::Perfect => true,
            FaultKind::Uniform { ber, .. } => ber <= 0.0,
            FaultKind::Voltage { millivolts } => {
                FaultProfile::ber_at(millivolts) <= 0.0
            }
            FaultKind::Mram { bin } => bin.base_ber() <= 0.0,
        }
    }

    /// Validate the spec. Every ingestion boundary calls this before a
    /// model is built — mirrors `CodecSpec::validate`.
    pub fn validate(&self) -> anyhow::Result<()> {
        match self.kind {
            FaultKind::Perfect => Ok(()),
            FaultKind::Uniform {
                ber,
                one_to_zero_fraction,
            } => {
                anyhow::ensure!(
                    (0.0..=1.0).contains(&ber) && ber.is_finite(),
                    "fault BER {ber} out of range [0, 1]"
                );
                anyhow::ensure!(
                    (0.0..=1.0).contains(&one_to_zero_fraction),
                    "1->0 fraction {one_to_zero_fraction} out of range [0, 1]"
                );
                Ok(())
            }
            FaultKind::Voltage { millivolts } => {
                anyhow::ensure!(
                    (FaultProfile::MIN_MV..=FaultProfile::NOMINAL_MV)
                        .contains(&millivolts),
                    "supply voltage {millivolts} mV outside the modelled \
                     scaling range [{}, {}] mV",
                    FaultProfile::MIN_MV,
                    FaultProfile::NOMINAL_MV
                );
                Ok(())
            }
            // Bins are a closed enum; anything parseable is valid.
            FaultKind::Mram { .. } => Ok(()),
        }
    }

    /// Short label for scenario rows / figure legends, e.g. `perfect`,
    /// `ber1e-4`, `vdd1050mV`. Faithful and collision-free: the exact
    /// BER is printed (no rounding), a non-default 1→0 fraction is
    /// appended as `:f<frac>` and a non-default seed as `@<seed>`, so
    /// distinct sweep cells never collapse to one label.
    pub fn label(&self) -> String {
        let mut label = match self.kind {
            FaultKind::Perfect => "perfect".to_string(),
            FaultKind::Uniform {
                ber,
                one_to_zero_fraction,
            } => {
                let mut l = format!("ber{ber:e}");
                if one_to_zero_fraction != Self::DEFAULT_ONE_TO_ZERO_FRACTION {
                    l.push_str(&format!(":f{one_to_zero_fraction}"));
                }
                l
            }
            FaultKind::Voltage { millivolts } => format!("vdd{millivolts}mV"),
            FaultKind::Mram { bin } => format!("mram{}", bin.label_suffix()),
        };
        if self.seed != Self::DEFAULT_SEED && !self.is_perfect() {
            label.push_str(&format!("@{}", self.seed));
        }
        label
    }

    /// Parse the uniform textual form shared by CLI flags and TOML:
    ///
    /// * `perfect`
    /// * `uniform:<ber>` or `uniform:<ber>:<one_to_zero_fraction>`
    /// * `voltage:<millivolts>`
    /// * `mram:<bin>` (bins: [`MramBin::NAMES`])
    ///
    /// any of which may carry an `@<seed>` suffix (`voltage:1050@7`).
    /// Unknown model names and malformed numbers are rejected — same
    /// "no silent knob absorption" contract as `CodecSpec::set_knob` —
    /// and every rejection names the offending token and lists what
    /// would have been accepted, so a typo in a sweep grid or CLI flag
    /// is a one-glance fix.
    pub fn parse(text: &str) -> anyhow::Result<FaultSpec> {
        let text = text.trim();
        let (body, seed) = match text.split_once('@') {
            Some((body, s)) => {
                let seed: u64 = s
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("fault seed {s:?}: {e}"))?;
                (body.trim(), seed)
            }
            None => (text, Self::DEFAULT_SEED),
        };
        let mut parts = body.split(':');
        let name = parts.next().unwrap_or("").trim().to_ascii_lowercase();
        let args: Vec<&str> = parts.map(|p| p.trim()).collect();
        let num = |what: &str, s: &str| -> anyhow::Result<f64> {
            s.parse::<f64>()
                .map_err(|e| anyhow::anyhow!("fault {what} {s:?}: {e}"))
        };
        let spec = match name.as_str() {
            "perfect" | "none" => {
                anyhow::ensure!(args.is_empty(), "perfect takes no arguments");
                FaultSpec::perfect()
            }
            "uniform" | "ber" => {
                anyhow::ensure!(
                    (1..=2).contains(&args.len()),
                    "uniform needs uniform:<ber>[:<one_to_zero_fraction>]"
                );
                let ber = num("BER", args[0])?;
                let frac = match args.get(1) {
                    Some(s) => num("1->0 fraction", s)?,
                    None => Self::DEFAULT_ONE_TO_ZERO_FRACTION,
                };
                FaultSpec {
                    kind: FaultKind::Uniform {
                        ber,
                        one_to_zero_fraction: frac,
                    },
                    seed: Self::DEFAULT_SEED,
                }
            }
            "voltage" | "vdd" => {
                anyhow::ensure!(
                    args.len() == 1,
                    "voltage needs voltage:<millivolts>"
                );
                let mv = num("voltage", args[0])?;
                anyhow::ensure!(
                    mv >= 0.0 && mv.fract() == 0.0,
                    "voltage must be a whole number of millivolts, got {mv}"
                );
                FaultSpec::voltage(mv as u32)
            }
            "mram" => {
                anyhow::ensure!(
                    args.len() == 1,
                    "mram needs mram:<bin>; valid bins: {}",
                    MramBin::NAMES.join(", ")
                );
                let bin = MramBin::parse(args[0]).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown MRAM bin {:?}; valid bins: {}",
                        args[0],
                        MramBin::NAMES.join(", ")
                    )
                })?;
                FaultSpec::mram(bin)
            }
            other => anyhow::bail!(
                "unknown fault model {other:?}; known: perfect, \
                 uniform:<ber>[:<frac>], voltage:<mV>, mram:<bin> \
                 (each optionally @<seed>)"
            ),
        };
        let spec = spec.with_seed(seed);
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a comma-separated fault axis, e.g.
    /// `perfect,voltage:1050,uniform:1e-4`.
    pub fn parse_list(text: &str) -> anyhow::Result<Vec<FaultSpec>> {
        let list: Vec<FaultSpec> = text
            .split(',')
            .map(FaultSpec::parse)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(!list.is_empty(), "empty fault list");
        Ok(list)
    }

    /// Build the model instance for one lane. Each `(shard, chip)` pair
    /// gets a decorrelated sub-seed, so lanes inject independent
    /// streams while the whole run stays a pure function of the base
    /// seed.
    pub fn build(&self, shard: usize, chip: usize) -> Box<dyn FaultModel> {
        let seed = lane_seed(self.seed, shard, chip);
        match self.kind {
            FaultKind::Perfect => Box::new(PerfectChannel),
            FaultKind::Uniform {
                ber,
                one_to_zero_fraction,
            } => Box::new(UniformBer::new(seed, ber, one_to_zero_fraction)),
            FaultKind::Voltage { millivolts } => {
                Box::new(FaultProfile::eden(millivolts).model(seed))
            }
            FaultKind::Mram { bin } => {
                if bin.base_ber() <= 0.0 {
                    // The reliable bin never flips: keep the fast path.
                    Box::new(PerfectChannel)
                } else {
                    Box::new(MramProfile::bin(bin).model(seed))
                }
            }
        }
    }
}

/// Decorrelate one lane's injection stream from its siblings: mix the
/// (shard, chip) coordinates in with a golden-ratio stride before the
/// RNG's own splitmix seeding. Adjacent base seeds and adjacent lanes
/// both land far apart.
fn lane_seed(seed: u64, shard: usize, chip: usize) -> u64 {
    let lane = ((shard as u64) << 8) | (chip as u64 + 1);
    seed ^ lane.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::WireWord;

    #[test]
    fn parse_accepts_the_documented_forms() {
        assert_eq!(FaultSpec::parse("perfect").unwrap(), FaultSpec::perfect());
        let u = FaultSpec::parse("uniform:1e-3").unwrap();
        assert_eq!(
            u.kind,
            FaultKind::Uniform {
                ber: 1e-3,
                one_to_zero_fraction: FaultSpec::DEFAULT_ONE_TO_ZERO_FRACTION
            }
        );
        let u = FaultSpec::parse("uniform:0.01:0.9@77").unwrap();
        assert_eq!(u.seed, 77);
        assert_eq!(
            u.kind,
            FaultKind::Uniform {
                ber: 0.01,
                one_to_zero_fraction: 0.9
            }
        );
        let v = FaultSpec::parse(" voltage:1050 ").unwrap();
        assert_eq!(v.kind, FaultKind::Voltage { millivolts: 1050 });
        assert!(!v.is_perfect());
        assert!(FaultSpec::parse("vdd:1250@3").unwrap().is_perfect());
        let m = FaultSpec::parse("mram:weak@5").unwrap();
        assert_eq!(m.kind, FaultKind::Mram { bin: MramBin::Weak });
        assert_eq!(m.seed, 5);
        assert!(!m.is_perfect());
        assert!(FaultSpec::parse("mram:reliable").unwrap().is_perfect());
        assert_eq!(
            FaultSpec::parse_list("perfect,voltage:1050,mram:scaled")
                .unwrap()
                .len(),
            3
        );
    }

    #[test]
    fn parse_errors_name_the_token_and_list_valid_values() {
        // Satellite contract: CLI `--faults`, run TOML and sweep grids
        // all route through this parser, so one good message serves
        // every boundary.
        let e = FaultSpec::parse("mram:wobbly").unwrap_err().to_string();
        assert!(e.contains("\"wobbly\""), "{e}");
        for bin in MramBin::NAMES {
            assert!(e.contains(bin), "{e} missing {bin}");
        }
        let e = FaultSpec::parse("sram:weak").unwrap_err().to_string();
        assert!(e.contains("\"sram\""), "{e}");
        for known in ["perfect", "uniform", "voltage", "mram"] {
            assert!(e.contains(known), "{e} missing {known}");
        }
        let e = FaultSpec::parse("mram").unwrap_err().to_string();
        assert!(e.contains("reliable") && e.contains("saturated"), "{e}");
    }

    #[test]
    fn parse_rejects_unknown_models_and_bad_numbers() {
        for bad in [
            "wat",
            "uniform",
            "uniform:lots",
            "uniform:2.0", // BER out of range
            "uniform:1e-3:1.5",
            "voltage",
            "voltage:12.5",
            "voltage:400", // below modelled range
            "voltage:1050@zzz",
            "perfect:1",
            "mram",
            "mram:wobbly",
            "mram:weak:extra",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "{bad:?} accepted");
        }
        assert!(FaultSpec::parse_list("").is_err());
    }

    #[test]
    fn labels_are_stable_faithful_and_collision_free() {
        assert_eq!(FaultSpec::perfect().label(), "perfect");
        assert_eq!(FaultSpec::uniform(1e-4).label(), "ber1e-4");
        assert_eq!(FaultSpec::voltage(1050).label(), "vdd1050mV");
        // The exact BER is printed, never rounded to one digit.
        assert_eq!(FaultSpec::uniform(1.5e-4).label(), "ber1.5e-4");
        // Distinct fractions / seeds get distinct labels.
        let a = FaultSpec::parse("uniform:1e-3:0.5").unwrap().label();
        let b = FaultSpec::parse("uniform:1e-3:0.9").unwrap().label();
        assert_ne!(a, b);
        assert_eq!(a, "ber1e-3:f0.5");
        let c = FaultSpec::parse("uniform:1e-3@1").unwrap().label();
        let d = FaultSpec::parse("uniform:1e-3@2").unwrap().label();
        assert_ne!(c, d);
        assert_eq!(d, "ber1e-3@2");
        assert_eq!(FaultSpec::voltage(1000).with_seed(9).label(), "vdd1000mV@9");
        assert_eq!(FaultSpec::mram(MramBin::Weak).label(), "mramWeak");
        assert_eq!(
            FaultSpec::mram(MramBin::Saturated).with_seed(3).label(),
            "mramSaturated@3"
        );
        // A non-default seed on a perfect spec changes nothing, so the
        // label stays clean.
        assert_eq!(FaultSpec::perfect().with_seed(9).label(), "perfect");
        assert_eq!(FaultSpec::mram(MramBin::Reliable).with_seed(9).label(), "mramReliable");
    }

    #[test]
    fn lane_seeds_decorrelate() {
        let mut seen = std::collections::BTreeSet::new();
        for shard in 0..4 {
            for chip in 0..8 {
                assert!(seen.insert(lane_seed(42, shard, chip)));
            }
        }
        assert_ne!(lane_seed(1, 0, 0), lane_seed(2, 0, 0));
    }

    #[test]
    fn stats_merge_adds() {
        let mut a = FaultStats {
            injected_bits: 3,
            injected_words: 2,
            observed_error_bits: 5,
            corrected_bits: 4,
            detected_bits: 2,
            residual_error_bits: 1,
            words: 10,
        };
        let b = FaultStats {
            injected_bits: 1,
            injected_words: 1,
            observed_error_bits: 2,
            corrected_bits: 1,
            detected_bits: 0,
            residual_error_bits: 1,
            words: 6,
        };
        a.merge(&b);
        assert_eq!(a.injected_bits, 4);
        assert_eq!(a.injected_words, 3);
        assert_eq!(a.observed_error_bits, 7);
        assert_eq!(a.corrected_bits, 5);
        assert_eq!(a.detected_bits, 2);
        assert_eq!(a.residual_error_bits, 2);
        assert_eq!(a.words, 16);
        assert!((a.injected_ber() - 4.0 / (16.0 * 64.0)).abs() < 1e-15);
        assert!((a.residual_error_rate() - 2.0 / (16.0 * 64.0)).abs() < 1e-15);
        assert!(FaultStats::default().injected_ber() == 0.0);
        assert!(FaultStats::default().residual_error_rate() == 0.0);
    }

    #[test]
    fn built_models_are_deterministic_per_lane() {
        let spec = FaultSpec::uniform(0.05).with_seed(9);
        let mut a = spec.build(1, 3);
        let mut b = spec.build(1, 3);
        let mut c = spec.build(1, 4);
        let mut same = true;
        let mut diff = false;
        for i in 0..256u64 {
            let word = i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut wa = WireWord::raw(word);
            let mut wb = WireWord::raw(word);
            let mut wc = WireWord::raw(word);
            a.corrupt(&mut wa);
            b.corrupt(&mut wb);
            c.corrupt(&mut wc);
            same &= wa == wb;
            diff |= wa != wc;
        }
        assert!(same, "same lane + seed must corrupt identically");
        assert!(diff, "sibling lanes must inject independent streams");
    }
}
