//! The fault models themselves: deterministic, seed-driven corruption
//! of the 8 data lines of one chip transfer.
//!
//! Scope: only the **data lines** (`WireWord::data`) are corrupted. The
//! sidebands (DBI, index, flag) are one line each and assumed hardened
//! — the same modelling choice SparkXD makes for its control metadata —
//! so a corrupted transfer is always a well-formed wire word whose
//! payload bits lie. The decoders are total over such words (a
//! fault-flipped one-hot index resolves through the receiver's priority
//! decoder, see [`crate::encoding::zac_dest`]), which is what lets
//! fault propagation through the mirrored tables be simulated instead
//! of panicking.

use crate::encoding::WireWord;
use crate::util::rng::Rng;

/// Deterministic wire-corruption hook. The one shared drive loop calls
/// [`FaultModel::corrupt`] once per *error-resilient* transfer, between
/// `transmit_batch` (energy already counted) and `decode_batch`.
///
/// Determinism contract: the flip sequence must be a pure function of
/// the model's seed and the calls made so far — no wall-clock or OS
/// entropy — so fixed-seed runs are byte-for-byte reproducible.
pub trait FaultModel: Send {
    /// Corrupt the data lines of one transfer in place; returns the
    /// number of bits flipped.
    fn corrupt(&mut self, wire: &mut WireWord) -> u32;

    /// False when the model can never flip a bit — lets the drive loop
    /// skip the per-word call entirely on the perfect path.
    fn is_active(&self) -> bool {
        true
    }
}

/// The historical no-fault channel.
pub struct PerfectChannel;

impl FaultModel for PerfectChannel {
    fn corrupt(&mut self, _wire: &mut WireWord) -> u32 {
        0
    }

    fn is_active(&self) -> bool {
        false
    }
}

/// 64 i.i.d. Bernoulli(p) draws over the low `bits` positions, packed
/// into a mask — sampled with geometric gap skipping, so the cost is
/// O(expected flips) RNG draws (one draw when nothing flips), not one
/// draw per bit. Exact per-bit distribution: P(bit set) = p.
pub(crate) fn bernoulli_mask(rng: &mut Rng, p: f64, bits: u32) -> u64 {
    debug_assert!(bits >= 1 && bits <= 64);
    let full = if bits == 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    };
    if p <= 0.0 {
        return 0;
    }
    if p >= 1.0 {
        return full;
    }
    let ln_q = (1.0 - p).ln(); // < 0
    let mut mask = 0u64;
    let mut i = 0u32;
    while i < bits {
        let u = rng.f64();
        if u <= 0.0 {
            break; // ln(0) -> gap beyond any word
        }
        // gap ~ Geometric(p): failures before the next success.
        let gap = (u.ln() / ln_q).floor();
        if gap >= (bits - i) as f64 {
            break;
        }
        i += gap as u32;
        mask |= 1u64 << i;
        i += 1;
    }
    mask
}

/// Split an overall BER and a 1→0 fraction into per-polarity rates.
/// On balanced data, a fraction `f` of all flips being 1→0 means the
/// stored-1 rate is `2 f · ber` and the stored-0 rate `2 (1-f) · ber`
/// (each polarity holds half the bits). Rates are clamped to [0, 1].
pub(crate) fn polarity_rates(ber: f64, one_to_zero_fraction: f64) -> (f64, f64) {
    let p_one = (2.0 * one_to_zero_fraction * ber).clamp(0.0, 1.0);
    let p_zero = (2.0 * (1.0 - one_to_zero_fraction) * ber).clamp(0.0, 1.0);
    (p_one, p_zero)
}

/// Uniform-BER model: every data line shares one bit-error rate, with
/// the 1→0/0→1 asymmetry of charge-loss errors.
pub struct UniformBer {
    rng: Rng,
    /// Flip probability for driven 1s (charge loss).
    p_one: f64,
    /// Flip probability for driven 0s.
    p_zero: f64,
}

impl UniformBer {
    pub fn new(seed: u64, ber: f64, one_to_zero_fraction: f64) -> UniformBer {
        let (p_one, p_zero) = polarity_rates(ber, one_to_zero_fraction);
        UniformBer {
            rng: Rng::new(seed),
            p_one,
            p_zero,
        }
    }
}

impl FaultModel for UniformBer {
    fn corrupt(&mut self, wire: &mut WireWord) -> u32 {
        let ones = wire.data;
        let m10 = bernoulli_mask(&mut self.rng, self.p_one, 64) & ones;
        let m01 = bernoulli_mask(&mut self.rng, self.p_zero, 64) & !ones;
        let flips = m10 | m01;
        wire.data ^= flips;
        flips.count_ones()
    }

    fn is_active(&self) -> bool {
        self.p_one > 0.0 || self.p_zero > 0.0
    }
}

/// Per-lane BER model: each of the chip's 8 data lines carries its own
/// flip probabilities (weak-column variation — the shape EDEN's DRAM
/// characterization reports). Bit `8·beat + line` of `WireWord::data`
/// rides line `line`, so lane `l`'s candidate positions are the bits
/// `l, l+8, …, l+56`.
pub struct PerLaneBer {
    rng: Rng,
    /// Per-line flip probability for driven 1s.
    p_one: [f64; 8],
    /// Per-line flip probability for driven 0s.
    p_zero: [f64; 8],
}

impl PerLaneBer {
    pub fn new(seed: u64, p_one: [f64; 8], p_zero: [f64; 8]) -> PerLaneBer {
        PerLaneBer {
            rng: Rng::new(seed),
            p_one,
            p_zero,
        }
    }
}

/// Deposit bit `b` of an 8-bit beat mask at word position `8·b` (line 0
/// of every flagged beat); shift by the line index to address line `l`.
fn spread_beats(m8: u64) -> u64 {
    let mut out = 0u64;
    let mut x = m8;
    while x != 0 {
        let b = x.trailing_zeros();
        out |= 1u64 << (8 * b);
        x &= x - 1;
    }
    out
}

impl FaultModel for PerLaneBer {
    fn corrupt(&mut self, wire: &mut WireWord) -> u32 {
        let mut flips = 0u64;
        for l in 0..8 {
            let c1 = spread_beats(bernoulli_mask(&mut self.rng, self.p_one[l], 8)) << l;
            let c0 = spread_beats(bernoulli_mask(&mut self.rng, self.p_zero[l], 8)) << l;
            flips |= (c1 & wire.data) | (c0 & !wire.data);
        }
        wire.data ^= flips;
        flips.count_ones()
    }

    fn is_active(&self) -> bool {
        self.p_one.iter().chain(&self.p_zero).any(|&p| p > 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::seeded_rng;

    #[test]
    fn bernoulli_mask_edge_probabilities() {
        let mut r = seeded_rng(1);
        assert_eq!(bernoulli_mask(&mut r, 0.0, 64), 0);
        assert_eq!(bernoulli_mask(&mut r, 1.0, 64), u64::MAX);
        assert_eq!(bernoulli_mask(&mut r, 1.0, 8), 0xFF);
        for _ in 0..1000 {
            assert_eq!(bernoulli_mask(&mut r, 0.3, 8) & !0xFF, 0);
        }
    }

    #[test]
    fn bernoulli_mask_rate_matches_p() {
        let mut r = seeded_rng(2);
        for p in [0.01f64, 0.1, 0.5, 0.9] {
            let n = 4000;
            let set: u64 = (0..n)
                .map(|_| bernoulli_mask(&mut r, p, 64).count_ones() as u64)
                .sum();
            let rate = set as f64 / (n as f64 * 64.0);
            assert!(
                (rate - p).abs() < 0.02,
                "p={p}: measured {rate}"
            );
        }
    }

    #[test]
    fn uniform_ber_respects_polarity_asymmetry() {
        // All-ones words can only lose bits at p_one; all-zero words can
        // only gain bits at p_zero. With a 0.75 bias the 1->0 rate is
        // three times the 0->1 rate.
        let mut m = UniformBer::new(3, 0.05, 0.75);
        let (mut ones_flips, mut zeros_flips) = (0u64, 0u64);
        for _ in 0..4000 {
            let mut w = crate::encoding::WireWord::raw(u64::MAX);
            ones_flips += m.corrupt(&mut w) as u64;
            assert_eq!(w.data | u64::MAX, u64::MAX); // only 1->0 possible
            let mut z = crate::encoding::WireWord::raw(0);
            zeros_flips += m.corrupt(&mut z) as u64;
        }
        assert!(ones_flips > 0 && zeros_flips > 0);
        let ratio = ones_flips as f64 / zeros_flips as f64;
        assert!(
            (2.0..4.5).contains(&ratio),
            "1->0 / 0->1 ratio {ratio} far from 3"
        );
    }

    #[test]
    fn corrupt_reports_exact_flip_count() {
        let mut m = UniformBer::new(5, 0.2, 0.5);
        for i in 0..500u64 {
            let orig = i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut w = crate::encoding::WireWord::raw(orig);
            let n = m.corrupt(&mut w);
            assert_eq!((w.data ^ orig).count_ones(), n);
            // Sidebands untouched.
            assert_eq!(w.dbi_mask, 0);
            assert!(!w.index_used);
        }
    }

    #[test]
    fn per_lane_model_confines_flips_to_hot_lanes() {
        let mut p_one = [0.0; 8];
        let mut p_zero = [0.0; 8];
        p_one[3] = 0.5;
        p_zero[3] = 0.5;
        let mut m = PerLaneBer::new(7, p_one, p_zero);
        let lane3 = 0x0101_0101_0101_0101u64 << 3;
        let mut flipped = 0u64;
        for i in 0..500u64 {
            let orig = i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut w = crate::encoding::WireWord::raw(orig);
            m.corrupt(&mut w);
            flipped |= w.data ^ orig;
        }
        assert_ne!(flipped, 0);
        assert_eq!(flipped & !lane3, 0, "flips escaped lane 3");
    }

    #[test]
    fn spread_beats_deposits_one_bit_per_beat() {
        assert_eq!(spread_beats(0), 0);
        assert_eq!(spread_beats(0b1), 1);
        assert_eq!(spread_beats(0b1000_0001), (1u64 << 56) | 1);
        assert_eq!(spread_beats(0xFF), 0x0101_0101_0101_0101);
    }

    #[test]
    fn perfect_channel_is_inert() {
        let mut p = PerfectChannel;
        let mut w = crate::encoding::WireWord::raw(0xDEAD_BEEF);
        assert_eq!(p.corrupt(&mut w), 0);
        assert_eq!(w.data, 0xDEAD_BEEF);
        assert!(!p.is_active());
    }
}
