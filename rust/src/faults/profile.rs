//! EDEN-style voltage-binned DRAM error profiles: the supply-voltage
//! knob maps to a base bit-error rate, which is then spread across the
//! chip's 8 data lanes with deterministic weak-column variation.
//!
//! Shape (EDEN, arXiv:1910.05340, Fig. 4): DRAM is error-free at the
//! nominal 1.25 V; as V_dd scales down the raw BER rises roughly one
//! decade per ~50 mV once cells start failing, saturating around 1e-2
//! at the lowest voltages characterized. Errors are dominated by charge
//! loss, i.e. weighted toward 1→0 flips.

use super::model::{polarity_rates, PerLaneBer};
use crate::util::rng::Rng;

/// A voltage-binned fault profile: base BER at a supply voltage plus
/// the per-lane weighting that turns it into a [`PerLaneBer`] model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultProfile {
    /// Supply voltage this profile models.
    pub millivolts: u32,
    /// Raw BER of the bin (per stored bit, before lane weighting).
    pub base_ber: f64,
    /// Fraction of flips that are 1→0 (charge loss).
    pub one_to_zero_fraction: f64,
}

impl FaultProfile {
    /// Nominal DDR4 V_dd (error-free).
    pub const NOMINAL_MV: u32 = 1250;
    /// Lowest supply voltage the bins model.
    pub const MIN_MV: u32 = 900;

    /// The voltage → BER bin table (lower bound of each bin, BER).
    /// Stepwise like EDEN's per-module characterization tables; the
    /// exact decades are representative, not device-specific.
    const BINS: [(u32, f64); 8] = [
        (1250, 0.0),
        (1200, 1e-7),
        (1150, 1e-6),
        (1100, 1e-5),
        (1050, 1e-4),
        (1000, 1e-3),
        (950, 5e-3),
        (900, 1e-2),
    ];

    /// The full voltage ladder, nominal first, BER ascending — the
    /// per-workload budget search walks this.
    pub fn ladder() -> &'static [(u32, f64)] {
        &Self::BINS
    }

    /// Base BER for a supply voltage: the bin whose lower bound the
    /// voltage reaches. `>= 1250 mV` is error-free.
    pub fn ber_at(millivolts: u32) -> f64 {
        for &(mv, ber) in &Self::BINS {
            if millivolts >= mv {
                return ber;
            }
        }
        // Below the modelled range; validation rejects this earlier,
        // but stay total and saturate.
        Self::BINS[Self::BINS.len() - 1].1
    }

    /// The profile for a supply voltage with the default charge-loss
    /// asymmetry.
    pub fn eden(millivolts: u32) -> FaultProfile {
        FaultProfile {
            millivolts,
            base_ber: Self::ber_at(millivolts),
            one_to_zero_fraction: super::FaultSpec::DEFAULT_ONE_TO_ZERO_FRACTION,
        }
    }

    /// Deterministic per-lane weakness weights in [0.25, 2.5): most
    /// lanes sit near the base rate, a few are markedly weaker — the
    /// squared-uniform skew gives the long tail DRAM column
    /// characterization shows. Pure function of `seed`.
    pub fn lane_weights(seed: u64) -> [f64; 8] {
        let mut r = Rng::new(seed ^ 0x1a_e5_ca_1e);
        let mut w = [0.0; 8];
        for slot in w.iter_mut() {
            let u = r.f64();
            *slot = 0.25 + 2.25 * u * u;
        }
        w
    }

    /// Build the per-lane model this profile describes for one lane
    /// seed (already decorrelated per (shard, chip) by the caller).
    pub fn model(&self, seed: u64) -> PerLaneBer {
        let weights = Self::lane_weights(seed);
        let mut p_one = [0.0; 8];
        let mut p_zero = [0.0; 8];
        for l in 0..8 {
            let (p1, p0) =
                polarity_rates(self.base_ber * weights[l], self.one_to_zero_fraction);
            p_one[l] = p1;
            p_zero[l] = p0;
        }
        PerLaneBer::new(seed, p_one, p_zero)
    }
}

/// Approximate-MRAM reliability bins — the second memory technology.
///
/// STT-MRAM fails differently from voltage-scaled DRAM (approximate-
/// MRAM characterization, arXiv:2105.14151): errors come from read
/// disturb (a read current accidentally *sets* the free layer) and
/// retention loss under a relaxed thermal-stability factor, so the
/// polarity bias runs **0→1-dominant** — the mirror image of DRAM's
/// charge-loss 1→0 bias — and cell-to-cell variation is mild and
/// roughly linear rather than DRAM's long weak-column tail. The bins
/// trade retention margin (and thus write energy, outside this model's
/// scope) for BER, analogous to EDEN's voltage bins.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MramBin {
    /// Full thermal-stability margin: error-free (the MRAM analogue of
    /// nominal voltage).
    Reliable,
    /// Slightly relaxed margin: BER 1e-4.
    Weak,
    /// Aggressively relaxed margin: BER 1e-3.
    Scaled,
    /// Deep approximation: BER 1e-2.
    Aggressive,
    /// Degenerate every-bit-flips bin (BER 1.0): not a physical
    /// operating point but the analytical edge case — deterministic
    /// full inversion, polarity bias moot.
    Saturated,
}

impl MramBin {
    /// All bins, mildest first.
    pub const ALL: [MramBin; 5] = [
        MramBin::Reliable,
        MramBin::Weak,
        MramBin::Scaled,
        MramBin::Aggressive,
        MramBin::Saturated,
    ];

    /// The textual bin names `mram:<bin>` accepts, in [`Self::ALL`]
    /// order (also what parse errors list).
    pub const NAMES: [&'static str; 5] =
        ["reliable", "weak", "scaled", "aggressive", "saturated"];

    /// Parse a bin name (case-insensitive). `None` for unknown names —
    /// the caller owns the error message so it can name the token.
    pub fn parse(name: &str) -> Option<MramBin> {
        let name = name.trim().to_ascii_lowercase();
        Self::NAMES
            .iter()
            .position(|&n| n == name)
            .map(|i| Self::ALL[i])
    }

    /// Lowercase name (the parse token).
    pub fn name(&self) -> &'static str {
        Self::NAMES[Self::ALL.iter().position(|b| b == self).unwrap()]
    }

    /// Capitalized suffix for scenario labels (`mramWeak`).
    pub fn label_suffix(&self) -> &'static str {
        match self {
            MramBin::Reliable => "Reliable",
            MramBin::Weak => "Weak",
            MramBin::Scaled => "Scaled",
            MramBin::Aggressive => "Aggressive",
            MramBin::Saturated => "Saturated",
        }
    }

    /// Raw BER of the bin (per stored bit, before lane weighting).
    pub fn base_ber(&self) -> f64 {
        match self {
            MramBin::Reliable => 0.0,
            MramBin::Weak => 1e-4,
            MramBin::Scaled => 1e-3,
            MramBin::Aggressive => 1e-2,
            MramBin::Saturated => 1.0,
        }
    }

    /// Fraction of flips that are 1→0. Read disturb dominates, so only
    /// a quarter of MRAM flips clear a bit (DRAM's default is 0.75 the
    /// other way). The saturated bin flips everything; 0.5 keeps both
    /// polarity rates at exactly 1.0 under [`polarity_rates`].
    pub fn one_to_zero_fraction(&self) -> f64 {
        match self {
            MramBin::Saturated => 0.5,
            _ => 0.25,
        }
    }
}

/// An MRAM reliability profile: bin BER plus the per-lane weighting
/// that turns it into a [`PerLaneBer`] model — the [`FaultProfile`]
/// analogue for the second technology.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MramProfile {
    pub bin: MramBin,
    pub base_ber: f64,
    pub one_to_zero_fraction: f64,
}

impl MramProfile {
    /// The profile for a reliability bin.
    pub fn bin(bin: MramBin) -> MramProfile {
        MramProfile {
            bin,
            base_ber: bin.base_ber(),
            one_to_zero_fraction: bin.one_to_zero_fraction(),
        }
    }

    /// Deterministic per-lane weights in [0.5, 1.5): linear (uniform)
    /// spread — MRAM's cell variation is mild, without DRAM's
    /// squared-uniform weak-column tail. Pure function of `seed`, and
    /// deliberately a *different* function than
    /// [`FaultProfile::lane_weights`] so the two technologies
    /// decorrelate even at equal seeds.
    pub fn lane_weights(seed: u64) -> [f64; 8] {
        let mut r = Rng::new(seed ^ 0x00AA_6E71_7E5E_ED00);
        let mut w = [0.0; 8];
        for slot in w.iter_mut() {
            *slot = 0.5 + r.f64();
        }
        w
    }

    /// Build the per-lane model for one (already lane-decorrelated)
    /// seed. The saturated bin skips lane weighting so every position
    /// flips with probability exactly 1 — the deterministic BER=1.0
    /// edge the fault-model tests pin.
    pub fn model(&self, seed: u64) -> PerLaneBer {
        let weights = if self.bin == MramBin::Saturated {
            [1.0; 8]
        } else {
            Self::lane_weights(seed)
        };
        let mut p_one = [0.0; 8];
        let mut p_zero = [0.0; 8];
        for l in 0..8 {
            let (p1, p0) =
                polarity_rates(self.base_ber * weights[l], self.one_to_zero_fraction);
            p_one[l] = p1;
            p_zero[l] = p0;
        }
        PerLaneBer::new(seed, p_one, p_zero)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::model::FaultModel;

    #[test]
    fn nominal_voltage_is_error_free() {
        assert_eq!(FaultProfile::ber_at(1250), 0.0);
        assert_eq!(FaultProfile::ber_at(1300), 0.0);
        assert!(!FaultProfile::eden(1250).model(1).is_active());
    }

    #[test]
    fn ber_rises_monotonically_as_voltage_drops() {
        let mut prev = -1.0;
        for mv in (900..=1250).rev().step_by(50) {
            let ber = FaultProfile::ber_at(mv);
            assert!(ber >= prev, "{mv} mV: {ber} < {prev}");
            prev = ber;
        }
        assert_eq!(FaultProfile::ber_at(1050), 1e-4);
        assert_eq!(FaultProfile::ber_at(1049), 1e-3);
        assert_eq!(FaultProfile::ber_at(900), 1e-2);
    }

    #[test]
    fn lane_weights_are_deterministic_and_bounded() {
        let a = FaultProfile::lane_weights(42);
        let b = FaultProfile::lane_weights(42);
        let c = FaultProfile::lane_weights(43);
        assert_eq!(a, b);
        assert_ne!(a, c);
        for w in a {
            assert!((0.25..2.5).contains(&w), "{w}");
        }
    }

    #[test]
    fn mram_bins_parse_and_order_by_severity() {
        assert_eq!(MramBin::parse("weak"), Some(MramBin::Weak));
        assert_eq!(MramBin::parse(" SATURATED "), Some(MramBin::Saturated));
        assert_eq!(MramBin::parse("wobbly"), None);
        let mut prev = -1.0;
        for bin in MramBin::ALL {
            assert_eq!(MramBin::parse(bin.name()), Some(bin));
            assert!(bin.base_ber() > prev, "{bin:?} out of order");
            prev = bin.base_ber();
        }
    }

    #[test]
    fn mram_reliable_bin_is_error_free() {
        assert!(!MramProfile::bin(MramBin::Reliable).model(1).is_active());
    }

    #[test]
    fn mram_saturated_bin_inverts_every_bit() {
        // BER = 1.0, both polarity rates clamp to 1: deterministic full
        // inversion regardless of seed or data.
        let mut m = MramProfile::bin(MramBin::Saturated).model(9);
        for word in [0u64, u64::MAX, 0xDEAD_BEEF_CAFE_F00D] {
            let mut w = crate::encoding::WireWord::raw(word);
            assert_eq!(m.corrupt(&mut w), 64);
            assert_eq!(w.data, !word);
        }
    }

    #[test]
    fn mram_polarity_is_zero_to_one_dominant() {
        // The mirror image of DRAM charge loss: all-zero words gain
        // bits ~3x as often as all-ones words lose them (f = 0.25).
        let mut m = MramProfile::bin(MramBin::Aggressive).model(11);
        let (mut ones_flips, mut zeros_flips) = (0u64, 0u64);
        for _ in 0..4000 {
            let mut w = crate::encoding::WireWord::raw(u64::MAX);
            ones_flips += m.corrupt(&mut w) as u64;
            let mut z = crate::encoding::WireWord::raw(0);
            zeros_flips += m.corrupt(&mut z) as u64;
            assert_eq!(z.data & !z.data, 0);
        }
        assert!(ones_flips > 0 && zeros_flips > 0);
        let ratio = zeros_flips as f64 / ones_flips as f64;
        assert!(
            (2.0..4.5).contains(&ratio),
            "0->1 / 1->0 ratio {ratio} far from 3"
        );
    }

    #[test]
    fn mram_lane_weights_differ_from_dram_at_equal_seed() {
        let m = MramProfile::lane_weights(42);
        let d = FaultProfile::lane_weights(42);
        assert_ne!(m, d);
        for w in m {
            assert!((0.5..1.5).contains(&w), "{w}");
        }
        assert_eq!(m, MramProfile::lane_weights(42));
        assert_ne!(m, MramProfile::lane_weights(43));
    }

    #[test]
    fn scaled_profile_injects_and_is_seed_stable() {
        let p = FaultProfile::eden(1000);
        assert_eq!(p.base_ber, 1e-3);
        let mut m1 = p.model(7);
        let mut m2 = p.model(7);
        assert!(m1.is_active());
        let mut flips = 0;
        for i in 0..20_000u64 {
            let word = i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut w = crate::encoding::WireWord::raw(word);
            let mut w2 = crate::encoding::WireWord::raw(word);
            flips += m1.corrupt(&mut w);
            m2.corrupt(&mut w2);
            assert_eq!(w, w2);
        }
        // 20k words x 64 bits x ~1e-3 weighted ~ O(1e3) flips.
        assert!(flips > 200, "only {flips} flips at 1e-3 BER");
    }
}
