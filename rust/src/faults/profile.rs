//! EDEN-style voltage-binned DRAM error profiles: the supply-voltage
//! knob maps to a base bit-error rate, which is then spread across the
//! chip's 8 data lanes with deterministic weak-column variation.
//!
//! Shape (EDEN, arXiv:1910.05340, Fig. 4): DRAM is error-free at the
//! nominal 1.25 V; as V_dd scales down the raw BER rises roughly one
//! decade per ~50 mV once cells start failing, saturating around 1e-2
//! at the lowest voltages characterized. Errors are dominated by charge
//! loss, i.e. weighted toward 1→0 flips.

use super::model::{polarity_rates, PerLaneBer};
use crate::util::rng::Rng;

/// A voltage-binned fault profile: base BER at a supply voltage plus
/// the per-lane weighting that turns it into a [`PerLaneBer`] model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultProfile {
    /// Supply voltage this profile models.
    pub millivolts: u32,
    /// Raw BER of the bin (per stored bit, before lane weighting).
    pub base_ber: f64,
    /// Fraction of flips that are 1→0 (charge loss).
    pub one_to_zero_fraction: f64,
}

impl FaultProfile {
    /// Nominal DDR4 V_dd (error-free).
    pub const NOMINAL_MV: u32 = 1250;
    /// Lowest supply voltage the bins model.
    pub const MIN_MV: u32 = 900;

    /// The voltage → BER bin table (lower bound of each bin, BER).
    /// Stepwise like EDEN's per-module characterization tables; the
    /// exact decades are representative, not device-specific.
    const BINS: [(u32, f64); 8] = [
        (1250, 0.0),
        (1200, 1e-7),
        (1150, 1e-6),
        (1100, 1e-5),
        (1050, 1e-4),
        (1000, 1e-3),
        (950, 5e-3),
        (900, 1e-2),
    ];

    /// Base BER for a supply voltage: the bin whose lower bound the
    /// voltage reaches. `>= 1250 mV` is error-free.
    pub fn ber_at(millivolts: u32) -> f64 {
        for &(mv, ber) in &Self::BINS {
            if millivolts >= mv {
                return ber;
            }
        }
        // Below the modelled range; validation rejects this earlier,
        // but stay total and saturate.
        Self::BINS[Self::BINS.len() - 1].1
    }

    /// The profile for a supply voltage with the default charge-loss
    /// asymmetry.
    pub fn eden(millivolts: u32) -> FaultProfile {
        FaultProfile {
            millivolts,
            base_ber: Self::ber_at(millivolts),
            one_to_zero_fraction: super::FaultSpec::DEFAULT_ONE_TO_ZERO_FRACTION,
        }
    }

    /// Deterministic per-lane weakness weights in [0.25, 2.5): most
    /// lanes sit near the base rate, a few are markedly weaker — the
    /// squared-uniform skew gives the long tail DRAM column
    /// characterization shows. Pure function of `seed`.
    pub fn lane_weights(seed: u64) -> [f64; 8] {
        let mut r = Rng::new(seed ^ 0x1a_e5_ca_1e);
        let mut w = [0.0; 8];
        for slot in w.iter_mut() {
            let u = r.f64();
            *slot = 0.25 + 2.25 * u * u;
        }
        w
    }

    /// Build the per-lane model this profile describes for one lane
    /// seed (already decorrelated per (shard, chip) by the caller).
    pub fn model(&self, seed: u64) -> PerLaneBer {
        let weights = Self::lane_weights(seed);
        let mut p_one = [0.0; 8];
        let mut p_zero = [0.0; 8];
        for l in 0..8 {
            let (p1, p0) =
                polarity_rates(self.base_ber * weights[l], self.one_to_zero_fraction);
            p_one[l] = p1;
            p_zero[l] = p0;
        }
        PerLaneBer::new(seed, p_one, p_zero)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::model::FaultModel;

    #[test]
    fn nominal_voltage_is_error_free() {
        assert_eq!(FaultProfile::ber_at(1250), 0.0);
        assert_eq!(FaultProfile::ber_at(1300), 0.0);
        assert!(!FaultProfile::eden(1250).model(1).is_active());
    }

    #[test]
    fn ber_rises_monotonically_as_voltage_drops() {
        let mut prev = -1.0;
        for mv in (900..=1250).rev().step_by(50) {
            let ber = FaultProfile::ber_at(mv);
            assert!(ber >= prev, "{mv} mV: {ber} < {prev}");
            prev = ber;
        }
        assert_eq!(FaultProfile::ber_at(1050), 1e-4);
        assert_eq!(FaultProfile::ber_at(1049), 1e-3);
        assert_eq!(FaultProfile::ber_at(900), 1e-2);
    }

    #[test]
    fn lane_weights_are_deterministic_and_bounded() {
        let a = FaultProfile::lane_weights(42);
        let b = FaultProfile::lane_weights(42);
        let c = FaultProfile::lane_weights(43);
        assert_eq!(a, b);
        assert_ne!(a, c);
        for w in a {
            assert!((0.25..2.5).contains(&w), "{w}");
        }
    }

    #[test]
    fn scaled_profile_injects_and_is_seed_stable() {
        let p = FaultProfile::eden(1000);
        assert_eq!(p.base_ber, 1e-3);
        let mut m1 = p.model(7);
        let mut m2 = p.model(7);
        assert!(m1.is_active());
        let mut flips = 0;
        for i in 0..20_000u64 {
            let word = i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut w = crate::encoding::WireWord::raw(word);
            let mut w2 = crate::encoding::WireWord::raw(word);
            flips += m1.corrupt(&mut w);
            m2.corrupt(&mut w2);
            assert_eq!(w, w2);
        }
        // 20k words x 64 bits x ~1e-3 weighted ~ O(1e3) flips.
        assert!(flips > 200, "only {flips} flips at 1e-3 BER");
    }
}
