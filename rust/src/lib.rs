//! # ZAC-DEST — Zero Aware Configurable Data Encoding by Skipping Transfer
//!
//! Full-system reproduction of *"Zero Aware Configurable Data Encoding by
//! Skipping Transfer for Error Resilient Applications"* (Jha et al., 2021).
//!
//! The crate is the Layer-3 rust coordinator of a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: the DRAM-channel
//!   data-encoding engines ([`encoding`], constructed through the open
//!   codec registry), the channel energy model ([`channel`]), the
//!   trace/reconstruction machinery ([`trace`]), the gate-level circuit
//!   overhead model ([`circuits`]), the [`coordinator`] and multi-channel
//!   [`system`] execution engines, and the unified [`session`] API
//!   (`Session::builder()` over every simulate path — see
//!   `ARCHITECTURE.md`), plus the runtime telemetry layer ([`obs`]).
//! * **Layer 2** — JAX compute graphs for the five evaluation workloads,
//!   AOT-lowered to HLO text in `artifacts/` and executed through
//!   [`runtime`] (PJRT CPU client; python never runs on the request path).
//! * **Layer 1** — Pallas kernels (matmul / conv / k-means / popcount)
//!   inside those graphs.
//!
//! See `DESIGN.md` for the complete system inventory and the experiment
//! index mapping every figure and table of the paper onto modules here.

pub mod channel;
pub mod circuits;
pub mod coordinator;
pub mod datasets;
pub mod encoding;
pub mod faults;
pub mod figures;
pub mod obs;
pub mod quality;
pub mod runtime;
pub mod session;
pub mod system;
pub mod testkit;
pub mod trace;
pub mod util;
pub mod workloads;

/// Crate-wide result alias (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
