//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`) once and
//! execute them from the request path. Python never runs here — the HLO
//! text was produced at build time by `python/compile/aot.py`.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`), not the
//! serialized proto: jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json_lite::Json;

/// Argument/output signature entry from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact's signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub args: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let doc = Json::parse(text)?;
        let mut artifacts = HashMap::new();
        for (name, meta) in doc.get("artifacts")?.as_obj()? {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                meta.get(key)?
                    .as_arr()?
                    .iter()
                    .enumerate()
                    .map(|(i, a)| {
                        Ok(TensorSpec {
                            name: a
                                .get("name")
                                .map(|n| n.as_str().unwrap_or("").to_string())
                                .unwrap_or_else(|_| format!("out{i}")),
                            shape: a
                                .get("shape")?
                                .as_arr()?
                                .iter()
                                .map(|d| d.as_usize())
                                .collect::<Result<_>>()?,
                            dtype: a.get("dtype")?.as_str()?.to_string(),
                        })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: meta.get("file")?.as_str()?.to_string(),
                    args: parse_specs("args")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }
        Ok(Manifest { artifacts })
    }
}

/// A typed host tensor crossing the runtime boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::F32(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::I32(data, shape.to_vec())
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32(vec![v], vec![1])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Tensor::F32(..) => "f32",
            Tensor::I32(..) => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(d, _) => Ok(d),
            _ => anyhow::bail!("tensor is {}, wanted f32", self.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(d, _) => Ok(d),
            _ => anyhow::bail!("tensor is {}, wanted i32", self.dtype()),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32(d, _) => Ok(d),
            _ => anyhow::bail!("tensor is i32, wanted f32"),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            Tensor::I32(d, _) => Ok(d),
            _ => anyhow::bail!("tensor is f32, wanted i32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        Ok(match self {
            Tensor::F32(d, _) => xla::Literal::vec1(d).reshape(&dims)?,
            Tensor::I32(d, _) => xla::Literal::vec1(d).reshape(&dims)?,
        })
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Tensor> {
        Ok(match spec.dtype.as_str() {
            "f32" => Tensor::F32(lit.to_vec::<f32>()?, spec.shape.clone()),
            "i32" => Tensor::I32(lit.to_vec::<i32>()?, spec.shape.clone()),
            other => anyhow::bail!("unsupported dtype {other}"),
        })
    }
}

/// Pack 64-bit channel words as the (N, 2) i32 lo/hi layout the
/// `trace_stats` / `trace_screen` artifacts expect.
pub fn pack_words_i32(words: &[u64]) -> Vec<i32> {
    words
        .iter()
        .flat_map(|w| [(*w as u32) as i32, ((*w >> 32) as u32) as i32])
        .collect()
}

/// Poison-tolerant lock, used for the executable cache: a panicked
/// compile on one thread must surface its own root cause *there*, not
/// turn every later `exec`/`precompile` into a poisoned-lock panic.
/// Recovery is sound here because the cache is insert-only `Arc`s — a
/// panic mid-update can at worst lose one insert, never tear an entry.
fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// The PJRT runtime: one compiled executable per artifact, compiled
/// lazily and cached.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    executables: std::sync::Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            executables: std::sync::Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts directory: `$ZAC_ARTIFACTS` or `artifacts/`
    /// (searched upward so tests work from the crate root).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("ZAC_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
            if !dir.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = lock_unpoisoned(&self.executables).get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?,
        );
        lock_unpoisoned(&self.executables).insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Force-compile a set of artifacts up front (warm start).
    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute an artifact with typed host tensors; returns the tuple
    /// elements as typed tensors. Arguments are validated against the
    /// manifest before anything touches PJRT.
    pub fn exec(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}"))?
            .clone();
        anyhow::ensure!(
            inputs.len() == spec.args.len(),
            "{name}: expected {} args, got {}",
            spec.args.len(),
            inputs.len()
        );
        for (t, a) in inputs.iter().zip(&spec.args) {
            anyhow::ensure!(
                t.shape() == a.shape.as_slice() && t.dtype() == a.dtype,
                "{name}: arg {:?} expects {:?}{:?}, got {:?}{:?}",
                a.name,
                a.dtype,
                a.shape,
                t.dtype(),
                t.shape()
            );
        }
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == spec.outputs.len(),
            "{name}: manifest says {} outputs, got {}",
            spec.outputs.len(),
            parts.len()
        );
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(l, s)| Tensor::from_literal(l, s))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `None` when the PJRT artifacts (or real xla bindings) are absent:
    /// the tests skip instead of failing so the hermetic build stays
    /// green; they run in full wherever `make artifacts` has run, and
    /// `ZAC_REQUIRE_ARTIFACTS=1` turns the skip into a failure on hosts
    /// where artifacts must exist.
    fn runtime() -> Option<Runtime> {
        match Runtime::load(Runtime::default_dir()) {
            Ok(rt) => Some(rt),
            Err(e) => {
                assert!(
                    std::env::var("ZAC_REQUIRE_ARTIFACTS").map_or(true, |v| v != "1"),
                    "ZAC_REQUIRE_ARTIFACTS=1 but PJRT runtime failed to load: {e}"
                );
                eprintln!("skipping PJRT runtime test (run `make artifacts`): {e}");
                None
            }
        }
    }

    #[test]
    fn poisoned_executable_cache_lock_recovers() {
        // Regression: the cache used `lock().unwrap()`, so one panicked
        // compile poisoned the mutex and every later lookup died on the
        // poison instead of the root cause. `lock_unpoisoned` must hand
        // back a usable guard over intact contents.
        let cache: std::sync::Mutex<HashMap<String, i32>> = std::sync::Mutex::new(HashMap::new());
        lock_unpoisoned(&cache).insert("before".into(), 1);
        let poison = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = cache.lock().unwrap();
            panic!("compile blew up while holding the cache lock");
        }));
        assert!(poison.is_err());
        assert!(cache.is_poisoned(), "setup must actually poison the lock");
        // Both code paths of `Runtime::executable`: read-through hit...
        assert_eq!(lock_unpoisoned(&cache).get("before"), Some(&1));
        // ...and insert after a miss.
        lock_unpoisoned(&cache).insert("after".into(), 2);
        assert_eq!(lock_unpoisoned(&cache).len(), 2);
    }

    #[test]
    fn manifest_parses() {
        let Some(m) = runtime() else { return };
        assert!(m.manifest().artifacts.contains_key("trace_stats"));
        let spec = &m.manifest().artifacts["cnn_train_step"];
        assert_eq!(spec.args[0].shape, vec![32, 32, 32, 3]);
        assert_eq!(spec.outputs.last().unwrap().shape, vec![1]);
    }

    #[test]
    fn trace_stats_executes_and_matches_popcount() {
        let Some(rt) = runtime() else { return };
        // Seed-audit: the canonical seeded_rng stream, not an ad-hoc stride.
        let mut r = crate::util::rng::seeded_rng(0x57A7);
        let words: Vec<u64> = (0..8192).map(|_| r.next_u64()).collect();
        let t = Tensor::i32(pack_words_i32(&words), &[8192, 2]);
        let out = rt.exec("trace_stats", &[t]).unwrap();
        let per_word = out[0].as_i32().unwrap();
        let total = out[1].as_i32().unwrap()[0];
        let expect: i64 = words.iter().map(|w| w.count_ones() as i64).sum();
        assert_eq!(total as i64, expect);
        assert_eq!(per_word[7], words[7].count_ones() as i32);
    }

    #[test]
    fn arg_validation_rejects_bad_shapes() {
        let Some(rt) = runtime() else { return };
        let bad = Tensor::i32(vec![0; 4], &[2, 2]);
        let err = rt.exec("trace_stats", &[bad]).unwrap_err().to_string();
        assert!(err.contains("expects"), "{err}");
        assert!(rt.exec("nope", &[]).is_err());
    }

    #[test]
    fn trace_screen_agrees_with_data_table() {
        use crate::encoding::DataTable;
        let Some(rt) = runtime() else { return };
        let mut table = DataTable::new(64);
        let mut r = crate::util::rng::seeded_rng(7);
        for _ in 0..64 {
            table.push(r.next_u64());
        }
        let words: Vec<u64> = (0..8192).map(|_| r.next_u64()).collect();
        let out = rt
            .exec(
                "trace_screen",
                &[
                    Tensor::i32(pack_words_i32(&words), &[8192, 2]),
                    Tensor::i32(pack_words_i32(table.snapshot()), &[64, 2]),
                ],
            )
            .unwrap();
        let res = out[0].as_i32().unwrap();
        for (i, &w) in words.iter().enumerate().step_by(97) {
            let hit = table.most_similar(w).unwrap();
            assert_eq!(res[2 * i] as u32, hit.distance, "word {i} dist");
            assert_eq!(res[2 * i + 1] as usize, hit.index, "word {i} idx");
        }
    }
}
