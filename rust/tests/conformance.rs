//! Registry conformance: every built-in scheme, the out-of-tree ROT1
//! fixture, and deliberately broken codecs that must *fail* the testkit
//! with a scheme-named message.
//!
//! The `#[ignore]`d exhaustive grid runs in CI's
//! `cargo test -- --include-ignored` conformance stage.

use zac_dest::encoding::{
    default_registry, ChipDecoder, ChipEncoder, Codec, CodecRegistry, CodecSpec, Scheme,
    WireWord,
};
use zac_dest::testkit::{
    assert_codec_conforms, assert_codec_conforms_in, assert_correcting_codec,
    check_codec_conforms, check_correcting_codec,
};

// --- The out-of-tree fixture from the v2 acceptance, now held to the
// --- same contract as the built-ins.

struct Rot1Encoder;
impl ChipEncoder for Rot1Encoder {
    fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
        WireWord::raw(word.rotate_left(1))
    }
    fn scheme(&self) -> Scheme {
        Scheme::Org // stats bucketing only; legacy enum is closed
    }
    fn reset(&mut self) {}
}

struct Rot1Decoder;
impl ChipDecoder for Rot1Decoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        wire.data.rotate_right(1)
    }
    fn reset(&mut self) {}
}

fn registry_with_rot1() -> CodecRegistry {
    let mut reg = default_registry().clone();
    reg.register("ROT1", |_spec| {
        Ok(Codec::new(Box::new(Rot1Encoder), Box::new(Rot1Decoder)))
    });
    reg
}

#[test]
fn all_five_builtin_schemes_conform() {
    for scheme in Scheme::all() {
        assert_codec_conforms(&CodecSpec::named(scheme.label()));
    }
}

#[test]
fn rot1_fixture_conforms_through_its_registry() {
    assert_codec_conforms_in(&registry_with_rot1(), &CodecSpec::named("ROT1"));
}

/// Every correcting scheme through the base invariants *and* the
/// correction laws: exact repair within the budget, check bits charged
/// (or provably absent), clean channel identical to the base scheme.
#[test]
fn all_correcting_schemes_conform() {
    // Per-beat Hamming: one flip per beat is within budget on any beat.
    assert_correcting_codec(
        &CodecSpec::named("SECDED"),
        Some(&CodecSpec::named("ORG")),
        2,
        true,
    );
    // Detect-only: a zero correction budget, but still transparent.
    assert_correcting_codec(
        &CodecSpec::named("PARITY"),
        Some(&CodecSpec::named("ORG")),
        0,
        true,
    );
    // In-band truncation: no base scheme (it is lossy by design) and no
    // sideband lines to pay for.
    assert_correcting_codec(&CodecSpec::named("EDEN"), None, 2, false);
    // The wrapper over every wrappable base: one whole-word flip.
    for base in ["ORG", "DBI", "BDE_ORG", "BDE", "OHE"] {
        assert_correcting_codec(
            &CodecSpec::named(&format!("ECC+{base}")),
            Some(&CodecSpec::named(base)),
            1,
            true,
        );
    }
}

/// A codec that *claims* a sideband but never drives the ECC line must
/// fail law 7 — check bits have to be paid for in both directions.
#[test]
fn undriven_sideband_fails_the_paid_for_law() {
    let err = check_correcting_codec(
        default_registry(),
        &CodecSpec::named("ORG"),
        None,
        0,
        true, // ORG drives no ECC line, so declaring one must fail
    )
    .unwrap_err();
    assert!(err.contains("sideband"), "{err}");
}

#[test]
fn small_table_variants_conform() {
    let mut bde = CodecSpec::named("BDE");
    bde.set_knob("table_size", "8").unwrap();
    assert_codec_conforms(&bde);
    let mut org_alg = CodecSpec::named("BDE_ORG");
    org_alg.set_knob("table_size", "16").unwrap();
    assert_codec_conforms(&org_alg);
}

// --- Broken fixtures: each violates exactly one invariant, and the
// --- testkit must catch it with a message naming the scheme.

/// Decoder drops the low bit: critical traffic is no longer exact.
struct LossyDecoder;
impl ChipDecoder for LossyDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        wire.data & !1
    }
    fn reset(&mut self) {}
}

/// Batch path diverges from scalar: the batch override XORs a marker.
struct SplitBrainEncoder;
impl ChipEncoder for SplitBrainEncoder {
    fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
        WireWord::raw(word)
    }
    fn encode_batch(&mut self, words: &[u64], approx: &[bool], out: &mut [WireWord]) {
        assert_eq!(words.len(), approx.len());
        for (&w, slot) in words.iter().zip(out.iter_mut()) {
            *slot = WireWord::raw(w ^ 0x8000_0000_0000_0000);
        }
    }
    fn scheme(&self) -> Scheme {
        Scheme::Org
    }
    fn reset(&mut self) {}
}

/// Passthrough pieces for the broken fixtures.
struct IdEncoder;
impl ChipEncoder for IdEncoder {
    fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
        WireWord::raw(word)
    }
    fn scheme(&self) -> Scheme {
        Scheme::Org
    }
    fn reset(&mut self) {}
}
struct IdDecoder;
impl ChipDecoder for IdDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        wire.data
    }
    fn reset(&mut self) {}
}

/// Zero words cost data-line energy: encodes 0 as a nonzero sentinel.
struct ExpensiveZeroEncoder;
impl ChipEncoder for ExpensiveZeroEncoder {
    fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
        WireWord::raw(if word == 0 { 0xFFFF } else { word })
    }
    fn scheme(&self) -> Scheme {
        Scheme::Org
    }
    fn reset(&mut self) {}
}
struct ExpensiveZeroDecoder;
impl ChipDecoder for ExpensiveZeroDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        if wire.data == 0xFFFF {
            0
        } else {
            wire.data
        }
    }
    fn reset(&mut self) {}
}

fn broken_registry() -> CodecRegistry {
    let mut reg = default_registry().clone();
    reg.register("BROKEN_LOSSY", |_spec| {
        Ok(Codec::new(Box::new(IdEncoder), Box::new(LossyDecoder)))
    });
    reg.register("BROKEN_BATCH", |_spec| {
        Ok(Codec::new(Box::new(SplitBrainEncoder), Box::new(IdDecoder)))
    });
    reg.register("BROKEN_ZERO", |_spec| {
        Ok(Codec::new(
            Box::new(ExpensiveZeroEncoder),
            Box::new(ExpensiveZeroDecoder),
        ))
    });
    reg
}

#[test]
fn broken_lossy_codec_fails_with_scheme_named_message() {
    let reg = broken_registry();
    let spec = CodecSpec::named("BROKEN_LOSSY");
    let err = check_codec_conforms(&reg, &spec).unwrap_err();
    assert!(err.contains("critical traffic"), "{err}");
    // The panicking entry point names the scheme.
    let panic = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        assert_codec_conforms_in(&reg, &spec);
    }))
    .unwrap_err();
    let msg = panic
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
        .expect("panic payload is a string");
    assert!(msg.contains("BROKEN_LOSSY"), "{msg}");
    assert!(msg.contains("failed conformance"), "{msg}");
}

#[test]
fn broken_batch_codec_is_caught_by_the_batch_contract() {
    let err = check_codec_conforms(&broken_registry(), &CodecSpec::named("BROKEN_BATCH"))
        .unwrap_err();
    assert!(err.contains("batch != scalar"), "{err}");
}

#[test]
fn broken_zero_codec_is_caught_by_zero_preservation() {
    let err = check_codec_conforms(&broken_registry(), &CodecSpec::named("BROKEN_ZERO"))
        .unwrap_err();
    assert!(err.contains("zero word"), "{err}");
}

/// Exhaustive knob-grid conformance (the CI `--include-ignored` stage):
/// the full paper grid of ZAC variants plus every table size worth
/// having, each through the whole invariant suite.
#[test]
#[ignore = "exhaustive grid; run in the CI conformance stage"]
fn exhaustive_knob_grid_conforms() {
    for limit in [90u32, 80, 75, 70, 60, 50] {
        for trunc in [0u32, 1, 2] {
            for tol in [0u32, 1, 2] {
                assert_codec_conforms(&CodecSpec::zac_full(limit, trunc, tol));
            }
        }
        assert_codec_conforms(&CodecSpec::zac_weights(limit));
    }
    for table_size in [1usize, 2, 8, 16, 32, 64] {
        for scheme in ["BDE", "BDE_ORG"] {
            let mut spec = CodecSpec::named(scheme);
            spec.set_knob("table_size", &table_size.to_string()).unwrap();
            assert_codec_conforms(&spec);
        }
        let mut zac = CodecSpec::zac(80);
        zac.set_knob("table_size", &table_size.to_string()).unwrap();
        assert_codec_conforms(&zac);
    }
}
