//! Telemetry acceptance: the metrics subsystem observes without
//! perturbing. Instrumented runs stay bit-identical to plain runs on
//! every execution engine, backpressure registers deterministically on
//! a starved mailbox (and stays zero on an idle one), and sweep
//! snapshots carry the stage/mailbox/latency keys CI greps out of the
//! `--metrics-out` artifact.

use std::time::Duration;

use zac_dest::channel::CHIPS;
use zac_dest::encoding::{
    ChipDecoder, ChipEncoder, Codec, CodecSpec, Scheme, WireWord, ENCODE_BATCH,
};
use zac_dest::faults::FaultSpec;
use zac_dest::session::{Execution, Session, Trace, TrafficClass};
use zac_dest::system::{run_sweep, synthetic_trace, AddressSpec, ChannelArray, SweepSpec};

fn session(spec: &CodecSpec, exec: Execution, channels: usize, telemetry: bool) -> Session {
    Session::builder()
        .codec(spec.clone())
        .channels(channels)
        .execution(exec)
        .traffic(TrafficClass::Approximate)
        .faults(FaultSpec::uniform(1e-3))
        .telemetry(telemetry)
        .build()
        .unwrap()
}

#[test]
fn instrumented_runs_are_bit_identical_on_every_engine() {
    let trace = Trace::from_bytes(synthetic_trace(40 * 64, 91));
    let spec = CodecSpec::zac_full(80, 1, 1);
    for (exec, channels) in [
        (Execution::Batch, 1),
        (Execution::Pipelined, 1),
        (Execution::Sharded, 2),
    ] {
        let plain = session(&spec, exec, channels, false).run(&trace).unwrap();
        let timed = session(&spec, exec, channels, true).run(&trace).unwrap();
        assert_eq!(plain.bytes, timed.bytes, "{exec:?}");
        assert_eq!(plain.counts, timed.counts, "{exec:?}");
        assert_eq!(plain.stats, timed.stats, "{exec:?}");
        assert_eq!(plain.faults, timed.faults, "{exec:?}");
        assert!(plain.telemetry.is_none(), "{exec:?}");
        let snap = timed.telemetry.expect("telemetry requested");
        assert!(snap.wall_ns > 0, "{exec:?}");
        assert_eq!(snap.lines, 40);
        let stage_total: u64 = snap.shards.iter().flat_map(|s| s.stage_ns).sum();
        assert!(stage_total > 0, "{exec:?}: no stage time recorded");
    }
}

#[test]
fn batch_run_snapshot_has_stage_time_but_no_mailbox_traffic() {
    let trace = Trace::from_bytes(synthetic_trace(64 * 64, 17));
    let spec = CodecSpec::named("BDE");
    let report = session(&spec, Execution::Batch, 1, true).run(&trace).unwrap();
    let snap = report.telemetry.unwrap();
    assert_eq!(snap.shards.len(), 1);
    let sh = &snap.shards[0];
    assert!(sh.stage_ns.iter().sum::<u64>() > 0);
    assert!(sh.batches > 0);
    // Batch execution has no mailbox: the backpressure and service
    // gauges stay at their idle zeros.
    assert_eq!(sh.mailbox_max_depth, 0);
    assert_eq!(sh.send_block_ns, 0);
    assert_eq!(sh.blocked_sends, 0);
    assert_eq!(sh.service_count, 0);
}

/// A deliberately slow shard worker: one sleep per encoded batch (not
/// per word) so the mailbox starves while the test stays fast.
struct SlowEncoder;

impl ChipEncoder for SlowEncoder {
    fn encode(&mut self, word: u64, _approx: bool) -> WireWord {
        WireWord::raw(word)
    }
    fn encode_batch(&mut self, words: &[u64], approx: &[bool], out: &mut [WireWord]) {
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(words.len(), approx.len());
        assert_eq!(words.len(), out.len());
        for (&w, slot) in words.iter().zip(out.iter_mut()) {
            *slot = WireWord::raw(w);
        }
    }
    fn scheme(&self) -> Scheme {
        Scheme::Org
    }
    fn reset(&mut self) {}
}

struct NopDecoder;

impl ChipDecoder for NopDecoder {
    fn decode(&mut self, wire: &WireWord) -> u64 {
        wire.data
    }
    fn reset(&mut self) {}
}

fn slow_array(telemetry: bool) -> ChannelArray {
    let codecs: Vec<_> = (0..CHIPS)
        .map(|_| Codec::new(Box::new(SlowEncoder), Box::new(NopDecoder)))
        .collect();
    // `ENCODE_BATCH` lines of mailbox = exactly one chunk deep.
    ChannelArray::with_codec_sets_faults_address_and_telemetry(
        vec![codecs],
        ENCODE_BATCH,
        &FaultSpec::perfect(),
        &AddressSpec::round_robin(),
        telemetry,
    )
}

#[test]
fn backpressure_registers_on_a_starved_one_chunk_mailbox() {
    // Regression for the backpressure accounting: a slow worker behind a
    // 1-chunk mailbox must drive the depth gauge to capacity and charge
    // nonzero send-block time; the producer outruns the worker by
    // construction (µs to build a chunk vs ≥16ms to serve one).
    let mut array = slow_array(true);
    let chunks = 6;
    for i in 0..chunks * ENCODE_BATCH {
        array.push_line([i as u64; CHIPS], true);
    }
    let out = array.finish(chunks * ENCODE_BATCH * 64);
    let snap = out.telemetry.expect("telemetry was on");
    let sh = &snap.shards[0];
    assert_eq!(sh.mailbox_max_depth, 1, "gauge must reach the 1-chunk cap");
    assert!(sh.blocked_sends > 0, "no send found the mailbox full");
    assert!(sh.send_block_ns > 0, "blocked sends must charge wall time");
    assert_eq!(sh.service_count, chunks as u64);
    assert!(sh.service_p50_ns >= 2_000_000, "p50 below one batch sleep");
    assert!(sh.service_p99_ns >= sh.service_p50_ns);
    // The passthrough codec still decodes bit-exactly under pressure.
    assert_eq!(out.bytes.len(), chunks * ENCODE_BATCH * 64);
}

#[test]
fn idle_array_reports_zero_backpressure() {
    // A roomy mailbox under a light load must not register pressure:
    // depth is sampled before each send, and nothing was in flight.
    let cfg = zac_dest::encoding::ZacConfig::zac(80);
    let sets = vec![(0..CHIPS).map(|_| Codec::from_config(&cfg)).collect()];
    let mut array = ChannelArray::with_codec_sets_faults_address_and_telemetry(
        sets,
        4 * ENCODE_BATCH,
        &FaultSpec::perfect(),
        &AddressSpec::round_robin(),
        true,
    );
    for i in 0..ENCODE_BATCH {
        array.push_line([i as u64 * 3; CHIPS], true);
    }
    let out = array.finish(ENCODE_BATCH * 64);
    let sh = &out.telemetry.unwrap().shards[0];
    assert_eq!(sh.mailbox_max_depth, 0);
    assert_eq!(sh.send_block_ns, 0);
    assert_eq!(sh.blocked_sends, 0);
    assert_eq!(sh.service_count, 1);
}

#[test]
fn sweep_telemetry_lands_in_report_json_and_metrics_artifact() {
    let spec = SweepSpec {
        bytes: 8192,
        channels: vec![2],
        schemes: vec!["BDE".into()],
        telemetry: true,
        ..SweepSpec::default()
    };
    let trace = synthetic_trace(spec.bytes, spec.seed);
    let report = run_sweep(&spec, &trace).unwrap();
    for sc in &report.scenarios {
        let snap = sc.telemetry.as_ref().expect("every cell instrumented");
        assert_eq!(snap.shards.len(), 2, "{}", sc.label);
        let stage_total: u64 = snap.shards.iter().flat_map(|s| s.stage_ns).sum();
        assert!(stage_total > 0, "{}", sc.label);
        assert!(snap.shards.iter().all(|s| s.service_count > 0));
    }
    // The grep keys land in BENCH_system.json and in the rendered table.
    let json = report.to_json().to_pretty();
    for key in ["\"stage_ns\"", "\"mailbox_max_depth\"", "\"service_p99_ns\""] {
        assert!(json.contains(key), "missing {key}");
    }
    assert!(report.render_table().contains("telemetry:"));
    // ... and in the --metrics-out artifact.
    let path = std::env::temp_dir().join("zac_telemetry_sweep_test.json");
    let path = path.to_str().unwrap();
    report.write_metrics(path).unwrap();
    let text = std::fs::read_to_string(path).unwrap();
    for key in ["\"stage_ns\"", "\"mailbox_max_depth\"", "\"service_p99_ns\""] {
        assert!(text.contains(key), "missing {key} in metrics artifact");
    }
    let parsed = zac_dest::util::json_lite::Json::parse(&text).unwrap();
    assert_eq!(
        parsed.get("scenarios").unwrap().as_arr().unwrap().len(),
        report.scenarios.len()
    );
    let _ = std::fs::remove_file(path);
}

#[test]
fn untelemetered_sweep_keeps_the_report_clean() {
    let spec = SweepSpec {
        bytes: 8192,
        channels: vec![1],
        schemes: vec!["BDE".into()],
        ..SweepSpec::default()
    };
    let trace = synthetic_trace(spec.bytes, spec.seed);
    let report = run_sweep(&spec, &trace).unwrap();
    assert!(report.scenarios.iter().all(|s| s.telemetry.is_none()));
    assert!(!report.render_table().contains("telemetry:"));
}
