//! Golden-vector fixtures: a small known input stream with the exact
//! wire words each of the five schemes must produce, committed so a
//! codec regression fails with a readable field-by-field diff instead
//! of a property-test shrink.
//!
//! The expected values were derived from the scalar encode path
//! (Table I semantics: ORG passthrough, DBI per-beat inversion, BDE_ORG
//! Algorithm 1, MBDC zero-bypass/index-aware/dedup, ZAC-DEST Algorithm
//! 2 with the final DBI stage) over this stream of eight words:
//!
//! | #  | word                  | why it is in the stream              |
//! |----|-----------------------|--------------------------------------|
//! | 0  | 0x0000000000000000    | zero-skip path, empty table          |
//! | 1  | 0xFF00000000000000    | first dense word (table miss)        |
//! | 2  | 0xFF00000000000000    | exact repeat (distance-0 hit)        |
//! | 3  | 0xFF00000000000001    | 1-bit neighbour (BDE/skip hit)       |
//! | 4  | 0x00000000000000F0    | sparse word where raw beats the xor  |
//! | 5  | 0xFFFFFFFFFFFFFFFF    | all-ones (DBI everywhere, far hit)   |
//! | 6  | 0x0000000000000000    | zero-skip with a warm table          |
//! | 7  | 0xFF000000000000FF    | second-generation table hit          |

use zac_dest::encoding::{default_registry, CodecSpec, Outcome, WireWord};

const W0: u64 = 0x0000_0000_0000_0000;
const W1: u64 = 0xFF00_0000_0000_0000;
const W3: u64 = 0xFF00_0000_0000_0001;
const W4: u64 = 0x0000_0000_0000_00F0;
const W5: u64 = 0xFFFF_FFFF_FFFF_FFFF;
const W7: u64 = 0xFF00_0000_0000_00FF;

/// The golden input stream (every access marked error-resilient).
const INPUT: [u64; 8] = [W0, W1, W1, W3, W4, W5, W0, W7];

/// One expected wire transfer: (data, dbi_mask, index_line, index_used,
/// outcome).
type GoldenWire = (u64, u8, u8, bool, Outcome);

fn wire(w: &GoldenWire) -> WireWord {
    WireWord {
        data: w.0,
        dbi_mask: w.1,
        index_line: w.2,
        index_used: w.3,
        ecc_line: 0,
        outcome: w.4,
    }
}

/// One expected transfer for a correcting scheme: the base fields plus
/// the hand-derived sideband word on the ECC line.
type GoldenEccWire = (GoldenWire, u64);

fn ecc_wire(w: &GoldenEccWire) -> WireWord {
    let mut out = wire(&w.0);
    out.ecc_line = w.1;
    out
}

/// Run the scalar encode/decode path and diff against the fixture with
/// a readable per-word message.
fn check(spec: &CodecSpec, golden: &[GoldenWire; 8], decoded: &[u64; 8]) {
    let mut codec = default_registry().build(spec).unwrap();
    for (i, (&word, want)) in INPUT.iter().zip(golden).enumerate() {
        let got = codec.encoder.encode(word, true);
        let want = wire(want);
        assert_eq!(
            got,
            want,
            "\n{} word {i} (input {word:#018x}):\n  got  data={:#018x} dbi={:#04x} \
             idx={} used={} outcome={:?}\n  want data={:#018x} dbi={:#04x} idx={} \
             used={} outcome={:?}\n",
            spec.label(),
            got.data,
            got.dbi_mask,
            got.index_line,
            got.index_used,
            got.outcome,
            want.data,
            want.dbi_mask,
            want.index_line,
            want.index_used,
            want.outcome,
        );
        let out = codec.decoder.decode(&got);
        assert_eq!(
            out, decoded[i],
            "{} word {i}: decoded {out:#018x}, fixture says {:#018x}",
            spec.label(),
            decoded[i]
        );
    }
}

/// The correcting-scheme variant of [`check`]: same diff style, with
/// the sideband word in the message so a check-bit regression reads as
/// an ECC-line mismatch rather than an opaque struct diff.
fn check_ecc(spec: &CodecSpec, golden: &[GoldenEccWire; 8], decoded: &[u64; 8]) {
    let mut codec = default_registry().build(spec).unwrap();
    for (i, (&word, want)) in INPUT.iter().zip(golden).enumerate() {
        let got = codec.encoder.encode(word, true);
        let want = ecc_wire(want);
        assert_eq!(
            got,
            want,
            "\n{} word {i} (input {word:#018x}):\n  got  data={:#018x} ecc={:#018x} \
             outcome={:?}\n  want data={:#018x} ecc={:#018x} outcome={:?}\n",
            spec.label(),
            got.data,
            got.ecc_line,
            got.outcome,
            want.data,
            want.ecc_line,
            want.outcome,
        );
        let out = codec.decoder.decode(&got);
        assert_eq!(
            out, decoded[i],
            "{} word {i}: decoded {out:#018x}, fixture says {:#018x}",
            spec.label(),
            decoded[i]
        );
    }
}

#[test]
fn golden_org() {
    let golden: [GoldenWire; 8] = [
        (W0, 0, 0, false, Outcome::ZeroSkip),
        (W1, 0, 0, false, Outcome::Raw),
        (W1, 0, 0, false, Outcome::Raw),
        (W3, 0, 0, false, Outcome::Raw),
        (W4, 0, 0, false, Outcome::Raw),
        (W5, 0, 0, false, Outcome::Raw),
        (W0, 0, 0, false, Outcome::ZeroSkip),
        (W7, 0, 0, false, Outcome::Raw),
    ];
    check(&CodecSpec::named("ORG"), &golden, &INPUT);
}

#[test]
fn golden_dbi() {
    // Per beat (byte): more than four 1s inverts the byte and raises
    // that beat's mask bit.
    let golden: [GoldenWire; 8] = [
        (0, 0x00, 0, false, Outcome::ZeroSkip),
        (0x0000_0000_0000_0000, 0x80, 0, false, Outcome::Raw), // byte7 inverted
        (0x0000_0000_0000_0000, 0x80, 0, false, Outcome::Raw),
        (0x0000_0000_0000_0001, 0x80, 0, false, Outcome::Raw),
        (W4, 0x00, 0, false, Outcome::Raw), // 0xF0 has exactly 4 ones: kept
        (0x0000_0000_0000_0000, 0xFF, 0, false, Outcome::Raw), // every byte inverted
        (0, 0x00, 0, false, Outcome::ZeroSkip),
        (0x0000_0000_0000_0000, 0x81, 0, false, Outcome::Raw), // bytes 0 and 7
    ];
    check(&CodecSpec::named("DBI"), &golden, &INPUT);
}

#[test]
fn golden_bde_org() {
    // Algorithm 1: the index line carries an address in BOTH branches
    // (raw branch = the FIFO slot the mirror must write); the table
    // updates only on raw transfers.
    let golden: [GoldenWire; 8] = [
        // slot 0 <- 0 (raw; zero classified for stats)
        (W0, 0, 0, true, Outcome::ZeroSkip),
        // 8 ones vs xor-with-0 = 8 ones: raw wins ties; slot 1 <- W1
        (W1, 0, 1, true, Outcome::Raw),
        // exact repeat: xor = 0 against slot 1
        (0x0000_0000_0000_0000, 0, 1, true, Outcome::Bde),
        // 1-bit neighbour of slot 1
        (0x0000_0000_0000_0001, 0, 1, true, Outcome::Bde),
        // 4 ones vs best xor (vs zero entry) 4 ones: raw; slot 2 <- W4
        (W4, 0, 2, true, Outcome::Raw),
        // all-ones vs slot 1: xor has 56 ones < 64: encoded
        (0x00FF_FFFF_FFFF_FFFF, 0, 1, true, Outcome::Bde),
        // zero hits the zero entry in slot 0: xor = 0 ones, 0 > 0 is
        // false: raw again; slot 3 <- 0
        (W0, 0, 3, true, Outcome::ZeroSkip),
        // 16 ones vs slot 1: xor = 0xFF (8 ones): encoded
        (0x0000_0000_0000_00FF, 0, 1, true, Outcome::Bde),
    ];
    check(&CodecSpec::named("BDE_ORG"), &golden, &INPUT);
}

#[test]
fn golden_bde_mbdc() {
    // MBDC: zero bypass (no index, no update), index-aware condition
    // hamming(word) > hamming(xor) + hamming(index), dedup update at
    // every non-zero access.
    let golden: [GoldenWire; 8] = [
        (0, 0, 0, false, Outcome::ZeroSkip), // zero bypass, table untouched
        (W1, 0, 0, false, Outcome::Raw),     // miss: raw, table <- W1 (slot 0)
        // repeat: 8 > 0 + hamming(idx 0) = 0: encoded; dist 0 so no push
        (0x0000_0000_0000_0000, 0, 0, true, Outcome::Bde),
        // neighbour: 9 > 1 + 0: encoded; table <- W3 (slot 1)
        (0x0000_0000_0000_0001, 0, 0, true, Outcome::Bde),
        // 4 ones vs xor 12 ones: raw; table <- W4 (slot 2)
        (W4, 0, 0, false, Outcome::Raw),
        // all-ones vs W3 (55-one xor, index 1 = 1 one): 64 > 56: encoded;
        // table <- W5 (slot 3)
        (0x00FF_FFFF_FFFF_FFFE, 0, 1, true, Outcome::Bde),
        (0, 0, 0, false, Outcome::ZeroSkip),
        // W7 vs W3: xor 0xFE (7 ones) + index 1 (1 one) < 16 ones: encoded
        (0x0000_0000_0000_00FE, 0, 1, true, Outcome::Bde),
    ];
    check(&CodecSpec::named("BDE"), &golden, &INPUT);
}

#[test]
fn golden_zac_dest_l80() {
    // ZAC-DEST at L80 (threshold: fewer than 13 dissimilar bits skips),
    // no truncation/tolerance, final DBI stage on everything that is
    // not a zero-skip. The skip puts the table index one-hot on the
    // data lines; exact fallbacks are MBDC + DBI.
    let golden: [GoldenWire; 8] = [
        (0, 0x00, 0, false, Outcome::ZeroSkip),
        // miss -> MBDC raw -> DBI inverts byte 7; table <- W1 (slot 0)
        (0x0000_0000_0000_0000, 0x80, 0, false, Outcome::Raw),
        // repeat: distance 0 < 13 -> skip, one-hot slot 0 on the data lines
        (0x0000_0000_0000_0001, 0x00, 0, false, Outcome::OheSkip),
        // 1 dissimilar bit -> skip to slot 0 (reconstructs W1, not W3)
        (0x0000_0000_0000_0001, 0x00, 0, false, Outcome::OheSkip),
        // 12 dissimilar bits vs W1 -> still inside the L80 envelope: skip
        (0x0000_0000_0000_0001, 0x00, 0, false, Outcome::OheSkip),
        // 56 dissimilar bits -> no skip; MBDC xor vs slot 0 (56 ones),
        // DBI inverts the seven 0xFF bytes; table <- W5 (slot 1)
        (0x0000_0000_0000_0000, 0x7F, 0, true, Outcome::Bde),
        (0, 0x00, 0, false, Outcome::ZeroSkip),
        // 8 dissimilar bits vs slot 0 -> skip again
        (0x0000_0000_0000_0001, 0x00, 0, false, Outcome::OheSkip),
    ];
    // The approximate reconstruction: skips substitute the table entry.
    let decoded: [u64; 8] = [0, W1, W1, W1, W1, W5, 0, W1];
    check(&CodecSpec::zac(80), &golden, &decoded);
}

#[test]
fn golden_secded() {
    // Per beat: Hamming checks c0..c3 on sideband bits 8b+0..3 and the
    // byte's overall parity on 8b+4. Hand values: 0x00 -> 0x00,
    // 0xFF -> 0x08 (only c3 covers bit 7), 0x01 -> 0x11 (c0 + parity),
    // 0xF0 -> 0x0C (c2, c3; four ones so parity stays even).
    let golden: [GoldenEccWire; 8] = [
        ((W0, 0, 0, false, Outcome::ZeroSkip), 0),
        ((W1, 0, 0, false, Outcome::Raw), 0x0800_0000_0000_0000),
        ((W1, 0, 0, false, Outcome::Raw), 0x0800_0000_0000_0000),
        ((W3, 0, 0, false, Outcome::Raw), 0x0800_0000_0000_0011),
        ((W4, 0, 0, false, Outcome::Raw), 0x0000_0000_0000_000C),
        ((W5, 0, 0, false, Outcome::Raw), 0x0808_0808_0808_0808),
        ((W0, 0, 0, false, Outcome::ZeroSkip), 0),
        ((W7, 0, 0, false, Outcome::Raw), 0x0800_0000_0000_0008),
    ];
    check_ecc(&CodecSpec::named("SECDED"), &golden, &INPUT);
}

#[test]
fn golden_parity() {
    // One sideband line: even parity of each byte at bit 8b. Every
    // stream byte except W3's 0x01 has an even population, so only
    // word 3 drives the line at all.
    let golden: [GoldenEccWire; 8] = [
        ((W0, 0, 0, false, Outcome::ZeroSkip), 0),
        ((W1, 0, 0, false, Outcome::Raw), 0),
        ((W1, 0, 0, false, Outcome::Raw), 0),
        ((W3, 0, 0, false, Outcome::Raw), 0x0000_0000_0000_0001),
        ((W4, 0, 0, false, Outcome::Raw), 0),
        ((W5, 0, 0, false, Outcome::Raw), 0),
        ((W0, 0, 0, false, Outcome::ZeroSkip), 0),
        ((W7, 0, 0, false, Outcome::Raw), 0),
    ];
    check_ecc(&CodecSpec::named("PARITY"), &golden, &INPUT);
}

#[test]
fn golden_eden() {
    // In-band truncation: every approximate byte travels as the
    // Hamming(7,4)+P codeword of its high nibble. encode(0xF) = 0xFF
    // and encode(0x0) = 0x00, so the dense stream maps onto itself with
    // low nibbles erased; decode returns `nibble << 4` per byte.
    let golden: [GoldenEccWire; 8] = [
        ((0, 0, 0, false, Outcome::ZeroSkip), 0),
        ((0xFF00_0000_0000_0000, 0, 0, false, Outcome::Bde), 0),
        ((0xFF00_0000_0000_0000, 0, 0, false, Outcome::Bde), 0),
        // W3's 0x01 low bit is below the truncation floor: gone.
        ((0xFF00_0000_0000_0000, 0, 0, false, Outcome::Bde), 0),
        ((0x0000_0000_0000_00FF, 0, 0, false, Outcome::Bde), 0),
        ((0xFFFF_FFFF_FFFF_FFFF, 0, 0, false, Outcome::Bde), 0),
        ((0, 0, 0, false, Outcome::ZeroSkip), 0),
        ((0xFF00_0000_0000_00FF, 0, 0, false, Outcome::Bde), 0),
    ];
    let decoded: [u64; 8] = [
        0,
        0xF000_0000_0000_0000,
        0xF000_0000_0000_0000,
        0xF000_0000_0000_0000,
        W4, // 0xF0's low nibble is already zero: exact
        0xF0F0_F0F0_F0F0_F0F0,
        0,
        0xF000_0000_0000_00F0,
    ];
    check_ecc(&CodecSpec::named("EDEN"), &golden, &decoded);
}

#[test]
fn golden_ecc_org() {
    // SECDED(72,64) over the (raw) ORG wire: whole-word checks c0..c6
    // at bits 8k, overall parity at bit 56. Hand-derived from the
    // column code (data bit i carries column i+1): the top byte's
    // columns 57..64 light c3..c6, bit 0 adds c0 and flips the overall
    // parity, and all-ones cancels every check except c6 (column 64).
    let golden: [GoldenEccWire; 8] = [
        ((W0, 0, 0, false, Outcome::ZeroSkip), 0),
        ((W1, 0, 0, false, Outcome::Raw), 0x0001_0101_0100_0000),
        ((W1, 0, 0, false, Outcome::Raw), 0x0001_0101_0100_0000),
        ((W3, 0, 0, false, Outcome::Raw), 0x0101_0101_0100_0001),
        ((W4, 0, 0, false, Outcome::Raw), 0x0000_0000_0101_0000),
        ((W5, 0, 0, false, Outcome::Raw), 0x0001_0000_0000_0000),
        ((W0, 0, 0, false, Outcome::ZeroSkip), 0),
        ((W7, 0, 0, false, Outcome::Raw), 0x0001_0101_0000_0000),
    ];
    check_ecc(&CodecSpec::named("ECC+ORG"), &golden, &INPUT);
}

/// The fixtures themselves round-trip: every exact scheme's decoded
/// fixture is the input, and the wire helpers preserve the fields.
#[test]
fn golden_fixture_sanity() {
    let g: GoldenWire = (0xAB, 0x01, 2, true, Outcome::Bde);
    let w = wire(&g);
    assert_eq!(w.data, 0xAB);
    assert_eq!(w.dbi_mask, 0x01);
    assert_eq!(w.index_line, 2);
    assert!(w.index_used);
    assert_eq!(w.ecc_line, 0);
    assert_eq!(w.outcome, Outcome::Bde);
    let e = ecc_wire(&(g, 0x55));
    assert_eq!(e.ecc_line, 0x55);
    assert_eq!(e.data, 0xAB);
}
