//! Cross-module integration tests: trace → encoders → channel →
//! reconstruction, property-based invariants over random configs, and
//! the energy-figure pipelines.

use zac_dest::channel::{EnergyCounts, CHIPS};
use zac_dest::coordinator::{
    simulate_bytes, simulate_f32s, simulate_lines, simulate_lines_per_chip, weight_chip_configs,
    Pipeline,
};
use zac_dest::encoding::{CodecSpec, EncodeStats, Outcome, Scheme, ZacConfig};
use zac_dest::session::{weight_chip_specs, Execution, Session, Trace, TrafficClass};
use zac_dest::system::ChannelArray;
use zac_dest::trace::{bytes_to_chip_words, chip_words_to_bytes, hex, ChipWords};
use zac_dest::util::prop;
use zac_dest::util::rng::seeded_rng;

// The one canonical image-like stream generator (identical walk).
use zac_dest::system::synthetic_trace as image_like;

#[test]
fn all_exact_schemes_lossless_on_all_traffic_shapes() {
    let mut r = seeded_rng(100);
    let streams: Vec<Vec<u8>> = vec![
        image_like(8192, 1),
        vec![0u8; 4096],                                        // all zeros
        (0..4096).map(|_| r.next_u32() as u8).collect(),        // random
        (0..4096).map(|i| ((i / 64) % 256) as u8).collect(),    // repetitive
    ];
    for bytes in &streams {
        for scheme in [Scheme::Org, Scheme::Dbi, Scheme::BdeOrg, Scheme::Bde] {
            let out = simulate_bytes(&ZacConfig::scheme(scheme), bytes, true);
            assert_eq!(&out.bytes, bytes, "{scheme:?} must be lossless");
        }
    }
}

#[test]
fn prop_zac_reconstruction_within_envelope_for_random_configs() {
    prop::check(
        "zac reconstruction envelope",
        101,
        |r| {
            let limit = [90u32, 80, 75, 70][r.range(0, 4)];
            let trunc = r.range(0, 3) as u64;
            let tol = r.range(0, 3) as u64;
            let len = r.range(64, 2048);
            let seed = r.next_u64();
            vec![limit as u64, trunc, tol, len as u64, seed]
        },
        |v| {
            let (limit, trunc, tol, len, seed) =
                (v[0] as u32, v[1] as u32, v[2] as u32, v[3] as usize, v[4]);
            let cfg = ZacConfig::zac_full(limit, trunc, tol);
            let bytes = image_like(len, seed);
            let out = simulate_bytes(&cfg, &bytes, true);
            let thr = cfg.dissimilar_threshold();
            let keep = !cfg.truncation_mask();
            let orig = bytes_to_chip_words(&bytes);
            let recon = bytes_to_chip_words(&out.bytes);
            for (a, b) in orig.iter().zip(&recon) {
                for j in 0..CHIPS {
                    let d = ((a[j] & keep) ^ b[j]).count_ones();
                    if d >= thr {
                        return Err(format!(
                            "chip word differs by {d} >= {thr} (limit {limit}, trunc {trunc})"
                        ));
                    }
                    // Tolerance bits must be exact.
                    if ((a[j] & keep) ^ b[j]) & cfg.tolerance_mask() != 0 {
                        return Err("tolerance bits approximated".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_non_approx_traffic_is_always_exact() {
    prop::check(
        "non-approx exactness",
        102,
        |r| {
            let len = r.range(64, 1024);
            (0..len).map(|_| r.next_u64()).collect::<Vec<u64>>()
        },
        |words| {
            let bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
            let out = simulate_bytes(&ZacConfig::zac(70), &bytes, false);
            if out.bytes == bytes {
                Ok(())
            } else {
                Err("critical traffic was approximated".into())
            }
        },
    );
}

#[test]
fn prop_energy_never_exceeds_org_baseline_by_much() {
    // Encoded schemes may add sideband overhead, but on similar streams
    // total termination must not blow up vs the unencoded baseline.
    prop::check(
        "termination sanity vs ORG",
        103,
        |r| vec![r.range(256, 4096) as u64, r.next_u64()],
        |v| {
            let bytes = image_like(v[0] as usize, v[1]);
            let base = simulate_bytes(&ZacConfig::scheme(Scheme::Org), &bytes, true);
            let zac = simulate_bytes(&ZacConfig::zac(80), &bytes, true);
            // Allow a small slack for flag/index sidebands.
            if zac.counts.termination_ones
                <= base.counts.termination_ones + base.counts.transfers * 8
            {
                Ok(())
            } else {
                Err(format!(
                    "zac {} vs org {}",
                    zac.counts.termination_ones, base.counts.termination_ones
                ))
            }
        },
    );
}

#[test]
fn savings_increase_monotonically_with_lower_limits() {
    let bytes = image_like(65536, 5);
    let base = simulate_bytes(&ZacConfig::scheme(Scheme::Bde), &bytes, true);
    let mut prev = f64::NEG_INFINITY;
    for limit in [95u32, 90, 85, 80, 75, 70, 65, 60] {
        let out = simulate_bytes(&ZacConfig::zac(limit), &bytes, true);
        let s = out.counts.termination_savings_vs(&base.counts);
        assert!(
            s + 1.0 >= prev, // allow 1% jitter from table-state divergence
            "L{limit}: savings {s:.2}% dropped below previous {prev:.2}%"
        );
        prev = prev.max(s);
    }
}

#[test]
fn truncation_strictly_reduces_energy() {
    let bytes = image_like(65536, 6);
    let t0 = simulate_bytes(&ZacConfig::zac_full(80, 0, 0), &bytes, true);
    let t1 = simulate_bytes(&ZacConfig::zac_full(80, 1, 0), &bytes, true);
    let t2 = simulate_bytes(&ZacConfig::zac_full(80, 2, 0), &bytes, true);
    assert!(t1.counts.termination_ones < t0.counts.termination_ones);
    assert!(t2.counts.termination_ones < t1.counts.termination_ones);
}

#[test]
fn tolerance_reduces_skip_rate_and_improves_fidelity() {
    let bytes = image_like(65536, 7);
    let loose = simulate_bytes(&ZacConfig::zac_full(70, 0, 0), &bytes, true);
    let tight = simulate_bytes(&ZacConfig::zac_full(70, 0, 2), &bytes, true);
    assert!(
        tight.stats.fraction(Outcome::OheSkip) <= loose.stats.fraction(Outcome::OheSkip),
        "tolerance must not increase the skip rate"
    );
    // Fidelity: mean absolute pixel error must improve with tolerance.
    let err = |out: &[u8]| -> f64 {
        bytes
            .iter()
            .zip(out)
            .map(|(&a, &b)| (a as f64 - b as f64).abs())
            .sum::<f64>()
            / bytes.len() as f64
    };
    assert!(err(&tight.bytes) <= err(&loose.bytes) + 1e-9);
}

#[test]
fn zero_heavy_traffic_hits_zero_skip_path() {
    // Sparse FMNIST-like traffic: most lines all-zero.
    let mut bytes = vec![0u8; 65536];
    let mut r = seeded_rng(8);
    for _ in 0..200 {
        let pos = r.range(0, bytes.len());
        bytes[pos] = r.next_u32() as u8;
    }
    let out = simulate_bytes(&ZacConfig::zac(80), &bytes, true);
    assert!(
        out.stats.fraction(Outcome::ZeroSkip) > 0.8,
        "zero-skip fraction {}",
        out.stats.fraction(Outcome::ZeroSkip)
    );
    // Zero words cost nothing.
    let dense = simulate_bytes(&ZacConfig::zac(80), &image_like(65536, 9), true);
    assert!(out.counts.termination_ones < dense.counts.termination_ones / 10);
}

#[test]
fn streaming_pipeline_equals_batch_for_every_scheme() {
    let bytes = image_like(16384, 10);
    let lines = bytes_to_chip_words(&bytes);
    for scheme in Scheme::all() {
        let cfg = if scheme == Scheme::ZacDest {
            ZacConfig::zac(75)
        } else {
            ZacConfig::scheme(scheme)
        };
        let batch = simulate_bytes(&cfg, &bytes, true);
        let mut p = Pipeline::new(&cfg, 8);
        for l in &lines {
            p.push_line(*l, true);
        }
        let streamed = p.finish(bytes.len());
        assert_eq!(streamed.bytes, batch.bytes, "{scheme:?}");
        assert_eq!(streamed.counts, batch.counts, "{scheme:?}");
    }
}

#[test]
fn prop_channel_array_bit_identical_to_single_channel_reference() {
    // Each shard of the array owns its own tables + line state, so for
    // shard counts 1/2/4 the array must be bit-identical — merged stats,
    // merged energy counts AND decoded bytes — to independent
    // single-channel `simulate_lines` runs over the round-robin
    // interleaved subsequences. With 1 shard the reference IS the plain
    // whole-trace single-channel path.
    prop::check(
        "channel array ≡ interleaved single-channel reference",
        104,
        |r| {
            let nlines = r.range(1, 48);
            let shards = [1u64, 2, 4][r.range(0, 3)];
            let limit = [90u64, 80, 75, 70][r.range(0, 4)];
            vec![nlines as u64, shards, limit, r.next_u64()]
        },
        |v| {
            let nlines = (v[0] as usize).clamp(1, 64);
            let shards = (v[1] as usize).clamp(1, 8);
            let limit = (v[2] as u32).clamp(50, 100);
            let bytes = image_like(nlines * 64, v[3]);
            let lines = bytes_to_chip_words(&bytes);
            let cfg = ZacConfig::zac(limit);
            let out = ChannelArray::run(&cfg, shards, &lines, true, bytes.len());

            let mut counts = EnergyCounts::default();
            let mut stats = EncodeStats::default();
            let mut ref_lines: Vec<ChipWords> = vec![[0u64; CHIPS]; lines.len()];
            for s in 0..shards {
                let sub: Vec<ChipWords> = lines.iter().skip(s).step_by(shards).copied().collect();
                let r = simulate_lines(&cfg, &sub, true, sub.len() * 64);
                counts.merge(&r.counts);
                stats.merge(&r.stats);
                for (i, l) in bytes_to_chip_words(&r.bytes).iter().enumerate() {
                    ref_lines[i * shards + s] = *l;
                }
            }
            if out.counts != counts {
                return Err(format!(
                    "energy counts diverge at {shards} shards: {:?} vs {:?}",
                    out.counts, counts
                ));
            }
            if out.stats != stats {
                return Err(format!(
                    "encode stats diverge at {shards} shards: {:?} vs {:?}",
                    out.stats, stats
                ));
            }
            let ref_bytes = chip_words_to_bytes(&ref_lines, bytes.len());
            if out.bytes != ref_bytes {
                return Err(format!("decoded bytes diverge at {shards} shards"));
            }
            Ok(())
        },
    );
}

#[test]
fn channel_array_single_shard_equals_whole_trace_reference_for_every_scheme() {
    let bytes = image_like(16384, 14);
    let lines = bytes_to_chip_words(&bytes);
    for scheme in Scheme::all() {
        let cfg = if scheme == Scheme::ZacDest {
            ZacConfig::zac(75)
        } else {
            ZacConfig::scheme(scheme)
        };
        let reference = simulate_bytes(&cfg, &bytes, true);
        let out = ChannelArray::run(&cfg, 1, &lines, true, bytes.len());
        assert_eq!(out.bytes, reference.bytes, "{scheme:?}");
        assert_eq!(out.counts, reference.counts, "{scheme:?}");
        assert_eq!(out.stats, reference.stats, "{scheme:?}");
    }
}

#[test]
fn sweep_engine_grid_runs_end_to_end() {
    use zac_dest::system::{run_sweep, synthetic_trace, SweepSpec};
    let spec = SweepSpec {
        bytes: 16384,
        channels: vec![1, 2],
        ..SweepSpec::default()
    };
    let trace = synthetic_trace(spec.bytes, spec.seed);
    let report = run_sweep(&spec, &trace).unwrap();
    assert!(report.scenarios.len() >= 6, "{}", report.scenarios.len());
    assert!(report.render_table().contains("term save"));
    // Exact baseline scenarios reconstruct the trace bit-exactly.
    for r in report.scenarios.iter().filter(|r| r.scheme == "BDE") {
        assert_eq!(r.quality_ratio, 1.0, "{}", r.label);
    }
}

/// The codec matrix the v2 acceptance pins: every scheme plus ZAC
/// variants exercising truncation, tolerance and the weights mask.
fn spec_matrix() -> Vec<CodecSpec> {
    vec![
        CodecSpec::named("ORG"),
        CodecSpec::named("DBI"),
        CodecSpec::named("BDE_ORG"),
        CodecSpec::named("BDE"),
        CodecSpec::zac(80),
        CodecSpec::zac_full(75, 2, 1),
        CodecSpec::zac_weights(60),
    ]
}

#[test]
fn session_pinned_bit_identical_to_legacy_paths_across_codec_matrix() {
    // Acceptance: Session::run must be bit-identical (bytes,
    // EncodeStats, EnergyCounts) to the legacy simulate_lines /
    // ChannelArray paths for every spec in the matrix at 1/2/4 channels.
    let bytes = image_like(300 * 64 + 32, 21);
    let lines = bytes_to_chip_words(&bytes);
    let trace = Trace::from_bytes(bytes.clone());
    for spec in spec_matrix() {
        let cfg = spec.to_config().unwrap();
        let single = simulate_lines(&cfg, &lines, true, bytes.len());
        for channels in [1usize, 2, 4] {
            let report = Session::builder()
                .codec(spec.clone())
                .channels(channels)
                .traffic(TrafficClass::Approximate)
                .build()
                .unwrap()
                .run(&trace)
                .unwrap();
            let legacy = ChannelArray::run(&cfg, channels, &lines, true, bytes.len());
            assert_eq!(report.bytes, legacy.bytes, "{} x{channels}", spec.label());
            assert_eq!(report.counts, legacy.counts, "{} x{channels}", spec.label());
            assert_eq!(report.stats, legacy.stats, "{} x{channels}", spec.label());
            if channels == 1 {
                assert_eq!(report.bytes, single.bytes, "{}", spec.label());
                assert_eq!(report.counts, single.counts, "{}", spec.label());
                assert_eq!(report.stats, single.stats, "{}", spec.label());
            }
            assert_eq!(report.channels(), channels, "{}", spec.label());
        }
    }
}

#[test]
fn prop_session_equals_legacy_on_random_traces() {
    let matrix = spec_matrix();
    prop::check(
        "Session::run ≡ legacy simulate/ChannelArray",
        107,
        |r| {
            let nlines = r.range(1, 40);
            let which = r.range(0, 7);
            let channels = [1u64, 2, 4][r.range(0, 3)];
            vec![nlines as u64, which as u64, channels, r.next_u64()]
        },
        |v| {
            let nlines = (v[0] as usize).clamp(1, 64);
            let spec = &matrix[(v[1] as usize) % matrix.len()];
            let channels = (v[2] as usize).clamp(1, 4);
            let bytes = image_like(nlines * 64, v[3]);
            let lines = bytes_to_chip_words(&bytes);
            let cfg = spec.to_config().unwrap();
            let legacy = ChannelArray::run(&cfg, channels, &lines, true, bytes.len());
            let report = Session::builder()
                .codec(spec.clone())
                .channels(channels)
                .traffic(TrafficClass::Approximate)
                .build()
                .map_err(|e| e.to_string())?
                .run(&Trace::from_bytes(bytes))
                .map_err(|e| e.to_string())?;
            if report.bytes != legacy.bytes {
                return Err(format!("{} x{channels}: bytes diverge", spec.label()));
            }
            if report.counts != legacy.counts {
                return Err(format!("{} x{channels}: counts diverge", spec.label()));
            }
            if report.stats != legacy.stats {
                return Err(format!("{} x{channels}: stats diverge", spec.label()));
            }
            Ok(())
        },
    );
}

#[test]
fn session_per_chip_specs_match_legacy_simulate_lines_per_chip() {
    // The weights projection: per-chip specs through a Session must
    // equal the legacy weight_chip_configs + simulate_lines_per_chip.
    let mut r = seeded_rng(23);
    let xs: Vec<f32> = (0..2048).map(|_| r.normal_f32(0.0, 0.05)).collect();
    let spec = CodecSpec::zac_weights(60);
    let cfg = spec.to_config().unwrap();
    let trace = Trace::from_f32s(&xs);
    let cfgs = weight_chip_configs(&cfg);
    let legacy = simulate_lines_per_chip(&cfgs, trace.lines(), true, trace.byte_len());
    let report = Session::builder()
        .codec_per_chip(weight_chip_specs(&spec).unwrap())
        .traffic(TrafficClass::Approximate)
        .build()
        .unwrap()
        .run(&trace)
        .unwrap();
    assert_eq!(report.bytes, legacy.bytes);
    assert_eq!(report.counts, legacy.counts);
    assert_eq!(report.stats, legacy.stats);
    // And the codec_weights convenience is the same projection.
    let via_weights = Session::builder()
        .codec_weights(spec)
        .traffic(TrafficClass::Approximate)
        .build()
        .unwrap()
        .run(&trace)
        .unwrap();
    assert_eq!(via_weights.bytes, report.bytes);
    assert_eq!(via_weights.counts, report.counts);
}

#[test]
fn session_pipelined_execution_matches_legacy_pipeline() {
    let bytes = image_like(16384, 25);
    let lines = bytes_to_chip_words(&bytes);
    let cfg = ZacConfig::zac(75);
    let mut p = Pipeline::new(&cfg, 8);
    for l in &lines {
        p.push_line(*l, true);
    }
    let legacy = p.finish(bytes.len());
    let report = Session::builder()
        .codec(CodecSpec::zac(75))
        .execution(Execution::Pipelined)
        .capacity_lines(8)
        .traffic(TrafficClass::Approximate)
        .build()
        .unwrap()
        .run(&Trace::from_bytes(bytes))
        .unwrap();
    assert_eq!(report.bytes, legacy.bytes);
    assert_eq!(report.counts, legacy.counts);
    assert_eq!(report.stats, legacy.stats);
}

#[test]
fn hex_trace_round_trips_through_simulation() {
    let bytes = image_like(4096, 11);
    let lines = bytes_to_chip_words(&bytes);
    let text = hex::emit(&lines);
    let parsed = hex::parse(&text).unwrap();
    assert_eq!(parsed, lines);
    let out = simulate_bytes(&ZacConfig::scheme(Scheme::Bde), &bytes, true);
    assert_eq!(out.bytes, bytes);
}

#[test]
fn weights_never_flip_sign_or_explode() {
    let mut r = seeded_rng(12);
    let xs: Vec<f32> = (0..8192).map(|_| r.normal_f32(0.0, 0.02)).collect();
    for limit in [70u32, 60, 50] {
        let (got, _) = simulate_f32s(&ZacConfig::zac_weights(limit), &xs, true);
        for (a, b) in xs.iter().zip(&got) {
            assert!(b.is_finite());
            assert!(a.signum() == b.signum() || *b == 0.0, "L{limit}: {a} -> {b}");
            assert!(b.abs() < a.abs() * 2.0 + 1e-12, "L{limit}: {a} -> {b}");
        }
    }
}

#[test]
fn figure_pipeline_renders_energy_figures() {
    use zac_dest::figures::{render, FigureCtx};
    use zac_dest::workloads::SuiteBudget;
    let ctx = FigureCtx::new(7, SuiteBudget::quick());
    for id in ["fig1", "fig2", "fig10", "fig14", "fig19", "fig22", "table1", "sec6"] {
        let out = render(&ctx, id).unwrap();
        assert!(out.contains('%') || out.contains("Table"), "{id}:\n{out}");
    }
}
