//! Edge-length coverage for the v2 `Trace` boundary: byte ⇄ line ⇄ f32
//! round-trips at awkward lengths (0, 1, non-multiple-of-line,
//! non-multiple-of-4 for f32), driven end-to-end through `Session`.

use zac_dest::encoding::CodecSpec;
use zac_dest::session::{Execution, Session, Trace, TrafficClass};
use zac_dest::trace::LINE_BYTES;
use zac_dest::util::rng::seeded_rng;

fn bytes(n: usize, seed: u64) -> Vec<u8> {
    let mut r = seeded_rng(seed);
    (0..n).map(|_| r.next_u32() as u8).collect()
}

/// The awkward byte lengths: empty, single byte, one-under/exact/
/// one-over a cache line, multi-line with ragged tails.
const EDGE_LENS: [usize; 9] = [
    0,
    1,
    LINE_BYTES - 1,
    LINE_BYTES,
    LINE_BYTES + 1,
    2 * LINE_BYTES + 7,
    5 * LINE_BYTES,
    5 * LINE_BYTES + 63,
    300 * LINE_BYTES + 32,
];

#[test]
fn trace_round_trips_bytes_at_every_edge_length() {
    for (i, &n) in EDGE_LENS.iter().enumerate() {
        let data = bytes(n, 100 + i as u64);
        let t = Trace::from_bytes(data.clone());
        assert_eq!(t.byte_len(), n);
        assert_eq!(t.line_count(), n.div_ceil(LINE_BYTES));
        assert_eq!(t.bytes(), &data[..]);
        // lines -> bytes -> lines is stable (padding is reproducible).
        let t2 = Trace::from_lines(t.lines().to_vec(), n);
        assert_eq!(t2.bytes(), t.bytes(), "len {n}");
        assert_eq!(t2.lines(), t.lines(), "len {n}");
    }
}

#[test]
fn session_is_lossless_at_every_edge_length_and_execution() {
    // An exact scheme through every execution engine must reproduce the
    // stream bit-exactly at every edge length, including the padded
    // tail trim.
    for (i, &n) in EDGE_LENS.iter().enumerate() {
        let data = bytes(n, 200 + i as u64);
        let trace = Trace::from_bytes(data.clone());
        for exec in [Execution::Batch, Execution::Pipelined, Execution::Sharded] {
            let report = Session::builder()
                .codec(CodecSpec::named("BDE"))
                .execution(exec)
                .traffic(TrafficClass::Approximate)
                .build()
                .unwrap()
                .run(&trace)
                .unwrap();
            assert_eq!(report.bytes, data, "len {n} {exec:?}");
            assert_eq!(
                report.stats.total(),
                (trace.line_count() * 8) as u64,
                "len {n} {exec:?}: transfers"
            );
        }
        // Sharded across more channels than (some traces have) lines.
        let report = Session::builder()
            .codec(CodecSpec::named("BDE"))
            .channels(4)
            .traffic(TrafficClass::Approximate)
            .build()
            .unwrap()
            .run(&trace)
            .unwrap();
        assert_eq!(report.bytes, data, "len {n} x4");
        assert_eq!(
            report.shards.iter().map(|s| s.lines).sum::<usize>(),
            trace.line_count(),
            "len {n} x4: shard coverage"
        );
    }
}

#[test]
fn empty_trace_yields_empty_report() {
    let report = Session::builder()
        .codec(CodecSpec::zac(80))
        .traffic(TrafficClass::Approximate)
        .build()
        .unwrap()
        .run(&Trace::from_bytes(Vec::new()))
        .unwrap();
    assert!(report.bytes.is_empty());
    assert_eq!(report.stats.total(), 0);
    assert_eq!(report.counts.transfers, 0);
    assert_eq!(report.faults.words, 0);
}

#[test]
fn f32_traces_round_trip_at_awkward_counts() {
    for count in [0usize, 1, 3, 15, 16, 17, 1023] {
        let mut r = seeded_rng(300 + count as u64);
        let xs: Vec<f32> = (0..count).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let trace = Trace::from_f32s(&xs);
        assert_eq!(trace.byte_len(), 4 * count);
        let report = Session::builder()
            .codec(CodecSpec::named("BDE"))
            .traffic(TrafficClass::Approximate)
            .build()
            .unwrap()
            .run(&trace)
            .unwrap();
        assert_eq!(report.to_f32s(), xs, "{count} floats");
    }
}

#[test]
#[should_panic(expected = "4-byte aligned")]
fn misaligned_f32_reinterpretation_panics_loudly() {
    // A byte trace whose length is not a multiple of 4 cannot be viewed
    // as f32s; the boundary fails loudly rather than truncating.
    let report = Session::builder()
        .codec(CodecSpec::named("ORG"))
        .build()
        .unwrap()
        .run(&Trace::from_bytes(bytes(10, 9)))
        .unwrap();
    let _ = report.to_f32s();
}

#[test]
fn from_lines_with_no_lines_is_empty() {
    let t = Trace::from_lines(Vec::new(), 0);
    assert_eq!(t.byte_len(), 0);
    assert_eq!(t.line_count(), 0);
}
