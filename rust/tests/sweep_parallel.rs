//! Parallel sweep engine acceptance: fanning the scenario grid across
//! the work-stealing pool is a pure wall-clock optimization — every
//! content figure is bit-identical to the sequential run — resume
//! re-runs exactly the missing cells and merges them indistinguishably
//! from a from-scratch sweep, and the open-loop load generator is
//! deterministic for a fixed seed and rate.

use zac_dest::faults::FaultSpec;
use zac_dest::session::Trace;
use zac_dest::system::{
    arrival_schedule, run_loadgen, run_sweep, run_sweep_resume, synthetic_trace, AddressSpec,
    LoadGenSpec, ScenarioResult, SweepReport, SweepSpec,
};

/// A grid that exercises every axis at once: 2 channel counts × 3
/// schemes (one knobbed) × 2 fault models × 2 address policies.
fn wide_spec(workers: usize) -> SweepSpec {
    SweepSpec {
        name: "par-acceptance".into(),
        bytes: 32 * 1024,
        seed: 9,
        channels: vec![1, 2],
        schemes: vec!["BDE".into(), "OHE".into(), "ECC+BDE".into()],
        limits: vec![80],
        truncations: vec![0],
        tolerances: vec![0],
        faults: vec![FaultSpec::perfect(), FaultSpec::voltage(1050)],
        address: vec![AddressSpec::round_robin(), AddressSpec::steer()],
        workers,
        ..SweepSpec::default()
    }
}

/// Everything a cell *measured*, excluding wall-clock noise (`wall_ms`,
/// `bytes_per_sec`, telemetry timings) — the figures the parallel
/// engine must reproduce bit-for-bit.
fn content_json(r: &ScenarioResult) -> String {
    let mut r = r.clone();
    r.wall_ms = 0.0;
    r.bytes_per_sec = 0.0;
    r.telemetry = None;
    r.to_json().to_string()
}

fn content_rows(rep: &SweepReport) -> Vec<String> {
    rep.scenarios.iter().map(content_json).collect()
}

#[test]
fn parallel_workers_match_sequential_bit_for_bit() {
    let trace = Trace::from_bytes(synthetic_trace(32 * 1024, 9));
    let seq = run_sweep(&wide_spec(1), &trace).unwrap();
    assert!(seq.scenarios.len() >= 20, "grid too small to be interesting");
    assert_eq!(seq.workers, 1);
    assert_eq!(seq.cells_run, seq.scenarios.len());
    assert_eq!(seq.cells_skipped, 0);
    assert!(seq.wall_s > 0.0);
    for workers in [2, 4] {
        let par = run_sweep(&wide_spec(workers), &trace).unwrap();
        assert_eq!(par.workers, workers);
        assert_eq!(
            content_rows(&seq),
            content_rows(&par),
            "workers={workers} diverged from sequential"
        );
    }
}

#[test]
fn resume_runs_exactly_the_missing_cells_and_merges_cleanly() {
    let trace = Trace::from_bytes(synthetic_trace(32 * 1024, 9));
    let spec = wide_spec(2);
    let full = run_sweep(&spec, &trace).unwrap();
    let n = full.scenarios.len();

    // Resuming a completed sweep re-runs nothing.
    let resumed = run_sweep_resume(&spec, &trace, Some(&full)).unwrap();
    assert_eq!(resumed.cells_run, 0);
    assert_eq!(resumed.cells_skipped, n);
    assert_eq!(content_rows(&resumed), content_rows(&full));

    // A half-finished report resumes exactly the missing half, and the
    // merged result is indistinguishable (on content) from the
    // from-scratch sweep — including rows carried over verbatim.
    let mut partial = full.clone();
    partial.scenarios.truncate(n / 2);
    let merged = run_sweep_resume(&spec, &trace, Some(&partial)).unwrap();
    assert_eq!(merged.cells_skipped, n / 2);
    assert_eq!(merged.cells_run, n - n / 2);
    assert_eq!(content_rows(&merged), content_rows(&full));
    // Carried-over rows are byte-identical clones, wall clock included.
    for (m, f) in merged.scenarios.iter().zip(&full.scenarios).take(n / 2) {
        assert_eq!(m.to_json().to_string(), f.to_json().to_string());
    }

    // The resume key survives the JSON artifact: parse the report back
    // from its serialized form and resume off that, as the CLI does.
    let reparsed = SweepReport::from_json(&full.to_json()).unwrap();
    let resumed = run_sweep_resume(&spec, &trace, Some(&reparsed)).unwrap();
    assert_eq!(resumed.cells_run, 0, "fingerprints must survive JSON");

    // A different trace invalidates every fingerprint — nothing resumes.
    let other = Trace::from_bytes(synthetic_trace(32 * 1024, 10));
    let fresh = run_sweep_resume(&spec, &other, Some(&full)).unwrap();
    assert_eq!(fresh.cells_run, n);
    assert_eq!(fresh.cells_skipped, 0);
}

#[test]
fn loadgen_is_deterministic_for_a_fixed_seed_and_rate() {
    // The schedule itself is a pure function of (rate, seed).
    assert_eq!(
        arrival_schedule(2e5, 64, 256, 0.2, 7),
        arrival_schedule(2e5, 64, 256, 0.2, 7)
    );
    // And so are the measured content figures: two runs at the same
    // offered rates agree on every count (latency percentiles are
    // wall-clock and may differ; content may not).
    let spec = wide_spec(1);
    let lg = LoadGenSpec::from_sweep(&spec, vec![1e11, 1e12]).unwrap();
    let trace = Trace::from_bytes(synthetic_trace(16 * 1024, 9));
    let a = run_loadgen(&lg, &trace).unwrap();
    let b = run_loadgen(&lg, &trace).unwrap();
    assert_eq!(a.steps.len(), 2);
    for (x, y) in a.steps.iter().zip(&b.steps) {
        assert_eq!(x.counts, y.counts);
        assert_eq!(x.lines, y.lines);
        assert_eq!(x.chunks, y.chunks);
    }
    // Every step carries the latency columns CI greps for.
    for st in &a.steps {
        assert!(st.service_p99_ns >= st.service_p95_ns);
        assert!(st.service_p95_ns >= st.service_p50_ns);
        assert!(st.telemetry.shards.iter().any(|s| s.service_count > 0));
    }
}
