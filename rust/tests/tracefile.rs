//! `.zactrace` end-to-end properties: a recorded trace replayed through
//! the mmap-backed reader is bit-identical to the live run across every
//! execution mode and shard count, every corruption mode surfaces as a
//! frame-indexed `WireError` (the decoder never panics), and the
//! builder/inspector surfaces (`trace_file`, `record_to`, `inspect`)
//! wire through the session layer.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use zac_dest::encoding::CodecSpec;
use zac_dest::faults::FaultSpec;
use zac_dest::session::{Execution, RunReport, Session, Trace, TrafficClass};
use zac_dest::system::synthetic_trace;
use zac_dest::trace::wire::{Layout, TraceFile, TraceWriter, WireError};
use zac_dest::trace::{bytes_to_chip_words, try_bytes_to_f32s};
use zac_dest::util::prop;

/// A unique scratch path per call, so parallel tests never collide.
fn temp_path(tag: &str) -> PathBuf {
    static N: AtomicUsize = AtomicUsize::new(0);
    let n = N.fetch_add(1, Ordering::Relaxed);
    let pid = std::process::id();
    std::env::temp_dir().join(format!("zac_tracefile_{pid}_{tag}_{n}.zactrace"))
}

fn session(spec: &CodecSpec, exec: Execution, channels: usize, faults: FaultSpec) -> Session {
    Session::builder()
        .codec(spec.clone())
        .channels(channels)
        .execution(exec)
        .faults(faults)
        .traffic(TrafficClass::Approximate)
        .build()
        .unwrap()
}

fn assert_reports_match(live: &RunReport, replayed: &RunReport, label: &str) {
    assert_eq!(live.bytes, replayed.bytes, "{label}: bytes diverge");
    assert_eq!(live.counts, replayed.counts, "{label}: counts diverge");
    assert_eq!(live.stats, replayed.stats, "{label}: stats diverge");
    assert_eq!(live.faults, replayed.faults, "{label}: faults diverge");
}

#[test]
fn recorded_replay_is_bit_identical_to_the_live_run_everywhere() {
    // The acceptance property: record → mmap replay produces the same
    // bytes / EncodeStats / EnergyCounts as the live in-memory run, for
    // every execution mode and 1/2/4 channels.
    let bytes = synthetic_trace(97 * 64 - 20, 61);
    let trace = Trace::from_bytes(bytes.clone());
    let path = temp_path("identity");
    trace.record(&path, true).unwrap();
    let file = TraceFile::open(&path).unwrap();
    file.verify_payloads().unwrap();
    assert_eq!(file.byte_len() as usize, bytes.len());
    assert_eq!(file.total_lines() as usize, trace.line_count());

    let cells = [
        (Execution::Batch, 1usize),
        (Execution::Pipelined, 1),
        (Execution::Auto, 1),
        (Execution::Sharded, 1),
        (Execution::Sharded, 2),
        (Execution::Auto, 2),
        (Execution::Sharded, 4),
        (Execution::Auto, 4),
    ];
    for spec in [CodecSpec::named("BDE"), CodecSpec::zac(80)] {
        for (exec, channels) in cells {
            let s = session(&spec, exec, channels, FaultSpec::perfect());
            let live = s.run(&trace).unwrap();
            let replayed = s.replay(&file).unwrap();
            let label = format!("{} {exec:?} x{channels}", spec.label());
            assert_reports_match(&live, &replayed, &label);
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn replay_preserves_fault_injection_bit_for_bit() {
    // Fault injection is seeded per shard stream, so the replayed
    // topology must reproduce the live injection exactly — including
    // the merged FaultStats.
    let bytes = synthetic_trace(64 * 64, 67);
    let trace = Trace::from_bytes(bytes);
    let path = temp_path("faults");
    trace.record(&path, true).unwrap();
    let file = TraceFile::open(&path).unwrap();
    for channels in [1usize, 2] {
        let s = session(
            &CodecSpec::named("BDE"),
            Execution::Auto,
            channels,
            FaultSpec::voltage(1050),
        );
        let live = s.run(&trace).unwrap();
        let replayed = s.replay(&file).unwrap();
        assert!(
            replayed.faults.injected_bits > 0,
            "x{channels}: the voltage model injected nothing"
        );
        assert_reports_match(&live, &replayed, &format!("vdd1050 x{channels}"));
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn prop_random_traces_replay_bit_identically() {
    prop::check(
        "random traces round-trip through the wire format",
        113,
        |r| {
            let nlines = r.range(1, 40);
            let shards = [1u64, 2, 4][r.range(0, 3)];
            let tail = r.range(0, 64);
            vec![nlines as u64, shards, tail as u64, r.next_u64()]
        },
        |v| {
            let nlines = (v[0] as usize).clamp(1, 64);
            let shards = (v[1] as usize).clamp(1, 4);
            let tail = (v[2] as usize).min(63);
            let nbytes = (nlines * 64).saturating_sub(tail).max(1);
            let trace = Trace::from_bytes(synthetic_trace(nbytes, v[3]));
            let path = temp_path("prop");
            if let Err(e) = trace.record(&path, true) {
                return Err(format!("record: {e}"));
            }
            let file = match TraceFile::open(&path) {
                Ok(f) => f,
                Err(e) => return Err(format!("open: {e}")),
            };
            let s = session(
                &CodecSpec::zac(80),
                Execution::Auto,
                shards,
                FaultSpec::perfect(),
            );
            let live = s.run(&trace).map_err(|e| format!("live: {e}"))?;
            let replayed = s.replay(&file).map_err(|e| format!("replay: {e}"))?;
            let _ = std::fs::remove_file(&path);
            if live.bytes != replayed.bytes {
                return Err(format!("x{shards}: replayed bytes diverge"));
            }
            if live.counts != replayed.counts || live.stats != replayed.stats {
                return Err(format!("x{shards}: replayed counters diverge"));
            }
            Ok(())
        },
    );
}

#[test]
fn irregular_frame_sizes_replay_identically() {
    // Frame boundaries are a recording artifact: the same stream cut
    // into 1/7/7/7/1-line frames must replay exactly like the live run,
    // single-channel and sharded.
    let bytes = synthetic_trace(23 * 64 - 8, 73);
    let trace = Trace::from_bytes(bytes.clone());
    let path = temp_path("irregular");
    let mut w = TraceWriter::create_with_chunk(&path, Layout::Raw, true, 7).unwrap();
    let lines = trace.lines();
    w.write_chunk(&lines[0..1], true).unwrap();
    w.write_chunk(&lines[1..8], true).unwrap();
    w.write_lines(&lines[8..], true).unwrap();
    w.write_chunk(&[], true).unwrap(); // empty append is a no-op
    let header = w.finish(bytes.len()).unwrap();
    assert_eq!(header.frame_count, 5);
    let file = TraceFile::open(&path).unwrap();
    assert_eq!(file.frame_lines(0), 1);
    assert_eq!(file.frame_lines(1), 7);
    for channels in [1usize, 2] {
        let s = session(
            &CodecSpec::named("BDE"),
            Execution::Auto,
            channels,
            FaultSpec::perfect(),
        );
        let live = s.run(&trace).unwrap();
        let replayed = s.replay(&file).unwrap();
        assert_reports_match(&live, &replayed, &format!("irregular x{channels}"));
    }
    let _ = std::fs::remove_file(&path);
}

/// Record a 10-line trace framed 4 lines per chunk — frames of 4, 4 and
/// 2 lines at fixed offsets (header 64 B, frame headers 16 B, lines
/// 64 B) — and return the path plus the raw file image for corruption
/// surgery.
fn small_recording(tag: &str) -> (PathBuf, Vec<u8>) {
    let bytes = synthetic_trace(10 * 64, 79);
    let lines = bytes_to_chip_words(&bytes);
    let path = temp_path(tag);
    let mut w = TraceWriter::create_with_chunk(&path, Layout::Raw, true, 4).unwrap();
    w.write_lines(&lines, true).unwrap();
    w.finish(bytes.len()).unwrap();
    let image = std::fs::read(&path).unwrap();
    assert_eq!(image.len(), 64 + 3 * 16 + 10 * 64);
    (path, image)
}

fn reopen(path: &Path, image: &[u8]) -> Result<TraceFile, WireError> {
    std::fs::write(path, image).unwrap();
    TraceFile::open(path)
}

#[test]
fn corruption_modes_are_named_errors_never_panics() {
    let (path, good) = small_recording("corrupt");
    let replay_session = session(
        &CodecSpec::named("BDE"),
        Execution::Auto,
        1,
        FaultSpec::perfect(),
    );

    // Bad magic: not a .zactrace at all.
    let mut bad = good.clone();
    bad[0] = b'X';
    assert!(matches!(reopen(&path, &bad), Err(WireError::BadMagic { .. })));

    // A future format version is refused up front.
    let mut bad = good.clone();
    bad[8..12].copy_from_slice(&9u32.to_le_bytes());
    assert!(matches!(
        reopen(&path, &bad),
        Err(WireError::UnsupportedVersion { found: 9, .. })
    ));

    // Any unsealed header field flip fails the header CRC.
    let mut bad = good.clone();
    bad[16] ^= 0x01;
    assert!(matches!(reopen(&path, &bad), Err(WireError::HeaderCorrupt { .. })));

    // A tail cut mid-frame: open succeeds (the prefix is readable), but
    // verify and replay name frame 2, and earlier frames still decode.
    let file = reopen(&path, &good[..good.len() - 12]).unwrap();
    assert_eq!(file.frame_count(), 2);
    let err = file.verify().unwrap_err();
    assert!(matches!(err, WireError::TruncatedFrame { frame: 2, .. }));
    let msg = err.to_string();
    assert!(msg.starts_with("frame 2: truncated frame"), "{msg}");
    assert!(file.chunk(0).is_ok());
    assert!(matches!(
        file.chunk(2),
        Err(WireError::TruncatedFrame { frame: 2, .. })
    ));
    let msg = replay_session.replay(&file).unwrap_err().to_string();
    assert!(msg.contains("frame 2"), "{msg}");

    // A tail cut exactly on a frame boundary: structurally clean, but
    // the header's frame count exposes the missing frame.
    let file = reopen(&path, &good[..good.len() - (16 + 2 * 64)]).unwrap();
    assert!(matches!(
        file.verify(),
        Err(WireError::FrameCountMismatch { header: 3, found: 2 })
    ));
    assert!(replay_session.replay(&file).is_err());

    // One flipped payload byte in frame 1: structure verifies, but the
    // frame's CRC names it, its chunk refuses to decode, and replay
    // fails — while frame 0 still reads.
    let mut bad = good.clone();
    bad[64 + (16 + 4 * 64) + 16 + 3] ^= 0x40;
    let file = reopen(&path, &bad).unwrap();
    file.verify().unwrap();
    let err = file.verify_payloads().unwrap_err();
    assert!(matches!(err, WireError::CrcMismatch { frame: 1, .. }));
    let msg = err.to_string();
    assert!(msg.starts_with("frame 1: crc mismatch"), "{msg}");
    assert!(file.chunk(0).is_ok());
    assert!(matches!(
        file.chunk(1),
        Err(WireError::CrcMismatch { frame: 1, .. })
    ));
    let msg = replay_session.replay(&file).unwrap_err().to_string();
    assert!(msg.contains("frame 1"), "{msg}");

    let _ = std::fs::remove_file(&path);
}

#[test]
fn misaligned_f32_streams_are_typed_errors_not_panics() {
    // The old `bytes_to_f32s` alignment panic, caught as data at every
    // file-ingestion boundary.
    let bytes = synthetic_trace(66, 83);
    let lines = bytes_to_chip_words(&bytes);
    let path = temp_path("f32");
    let mut w = TraceWriter::create(&path, Layout::F32Le, true).unwrap();
    w.write_lines(&lines, true).unwrap();
    w.finish(bytes.len()).unwrap();
    assert!(matches!(
        TraceFile::open(&path),
        Err(WireError::MisalignedF32 { byte_len: 66 })
    ));
    let _ = std::fs::remove_file(&path);

    assert_eq!(try_bytes_to_f32s(&[0u8; 8]).unwrap().len(), 2);
    assert!(matches!(
        try_bytes_to_f32s(&[0u8; 3]),
        Err(WireError::MisalignedF32 { byte_len: 3 })
    ));

    // A report over a non-f32-shaped stream reports, rather than
    // aborts, when asked for weights.
    let s = session(
        &CodecSpec::named("ORG"),
        Execution::Batch,
        1,
        FaultSpec::perfect(),
    );
    let report = s.run(&Trace::from_bytes(vec![1, 2, 3])).unwrap();
    assert_eq!(report.bytes.len(), 3);
    assert!(matches!(
        report.try_to_f32s(),
        Err(WireError::MisalignedF32 { byte_len: 3 })
    ));
}

#[test]
fn inspector_census_counts_zero_lines_and_corrupt_frames() {
    // 8 nonzero lines, 3 of them zeroed, framed in fours.
    let mut bytes = vec![0xA5u8; 8 * 64];
    for line in [1usize, 4, 6] {
        bytes[line * 64..(line + 1) * 64].fill(0);
    }
    let lines = bytes_to_chip_words(&bytes);
    let path = temp_path("census");
    let mut w = TraceWriter::create_with_chunk(&path, Layout::Raw, false, 4).unwrap();
    w.write_lines(&lines, false).unwrap();
    w.finish(bytes.len()).unwrap();

    let info = TraceFile::open(&path).unwrap().inspect();
    assert!(info.is_healthy());
    assert_eq!(info.total_lines, 8);
    assert_eq!(info.zero_lines, 3);
    assert!((info.zero_fraction() - 0.375).abs() < 1e-12);
    assert_eq!(info.frames.len(), 2);
    assert_eq!(info.frames[0].zero_lines, 1);
    assert_eq!(info.frames[1].zero_lines, 2);
    assert!(!info.frames[0].approx);
    let rendered = info.render();
    assert!(rendered.contains("status: ok"), "{rendered}");
    assert!(rendered.contains("critical"), "{rendered}");

    // Flip one byte in frame 1's payload: the census flags exactly that
    // frame without decoding anything.
    let mut image = std::fs::read(&path).unwrap();
    image[64 + (16 + 4 * 64) + 16 + 5] ^= 0x80;
    std::fs::write(&path, &image).unwrap();
    let info = TraceFile::open(&path).unwrap().inspect();
    assert!(!info.is_healthy());
    assert_eq!(info.corrupt_frames, 1);
    assert!(info.frames[0].crc_ok);
    assert!(!info.frames[1].crc_ok);
    let rendered = info.render();
    assert!(rendered.contains("MISMATCH"), "{rendered}");
    assert!(rendered.contains("1 corrupt frame(s)"), "{rendered}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn session_trace_file_and_record_to_builders_wire_through() {
    let bytes = synthetic_trace(41 * 64 - 4, 97);
    let trace = Trace::from_bytes(bytes.clone());

    // record_to: a live run leaves a verifiable recording behind.
    let recorded = temp_path("record_to");
    let live = Session::builder()
        .codec(CodecSpec::named("BDE"))
        .traffic(TrafficClass::Approximate)
        .record_to(&recorded)
        .build()
        .unwrap()
        .run(&trace)
        .unwrap();
    let file = TraceFile::open(&recorded).unwrap();
    file.verify_payloads().unwrap();
    assert!(file.header().traffic_approx);
    assert_eq!(Trace::from_file(&recorded).unwrap().bytes(), &bytes[..]);

    // trace_file + run_recorded: the one-call replay surface.
    let replayed = Session::builder()
        .codec(CodecSpec::named("BDE"))
        .traffic(TrafficClass::Approximate)
        .trace_file(&recorded)
        .build()
        .unwrap()
        .run_recorded()
        .unwrap();
    assert_reports_match(&live, &replayed, "run_recorded");

    // run_recorded without a configured file is a named error.
    let err = Session::builder()
        .codec(CodecSpec::named("BDE"))
        .build()
        .unwrap()
        .run_recorded()
        .unwrap_err()
        .to_string();
    assert!(err.contains("no trace file"), "{err}");
    let _ = std::fs::remove_file(&recorded);
}

#[test]
fn critical_recordings_stay_exact_under_an_approximate_session() {
    // Per-frame criticality survives the wire: a stream recorded as
    // critical must replay exactly even through a lossy session, because
    // the effective class is (session approx AND frame approx).
    let bytes = synthetic_trace(33 * 64, 71);
    let path = temp_path("critical");
    Trace::from_bytes(bytes.clone()).record(&path, false).unwrap();
    let file = TraceFile::open(&path).unwrap();
    assert!(!file.header().traffic_approx);
    assert!(!file.frame_approx(0));
    for channels in [1usize, 2] {
        let s = session(
            &CodecSpec::zac(80),
            Execution::Auto,
            channels,
            FaultSpec::perfect(),
        );
        let replayed = s.replay(&file).unwrap();
        assert_eq!(replayed.bytes, bytes, "x{channels}: went lossy");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn empty_traces_round_trip() {
    let path = temp_path("empty");
    Trace::from_bytes(Vec::new()).record(&path, true).unwrap();
    let file = TraceFile::open(&path).unwrap();
    file.verify_payloads().unwrap();
    assert_eq!(file.frame_count(), 0);
    assert_eq!(file.byte_len(), 0);
    assert!(Trace::from_file(&path).unwrap().bytes().is_empty());
    let s = session(
        &CodecSpec::named("BDE"),
        Execution::Auto,
        1,
        FaultSpec::perfect(),
    );
    let replayed = s.replay(&file).unwrap();
    assert!(replayed.bytes.is_empty());
    let _ = std::fs::remove_file(&path);
}
