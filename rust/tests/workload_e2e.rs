//! Heavy end-to-end tests: PJRT runtime + trained workloads. These need
//! `make artifacts` to have run; the quick budget keeps them ~1 min.
//! Without artifacts (or with the stub xla crate) they skip rather than
//! fail, so the hermetic CI stays green while full coverage runs
//! wherever PJRT is available.

use zac_dest::encoding::CodecSpec;
use zac_dest::faults::FaultSpec;
use zac_dest::runtime::Runtime;
use zac_dest::workloads::{Kind, Suite, SuiteBudget};

fn suite() -> Option<Suite> {
    let rt = match Runtime::load(Runtime::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            // ZAC_REQUIRE_ARTIFACTS=1 turns the skip into a failure on
            // hosts where artifacts are expected to exist.
            assert!(
                std::env::var("ZAC_REQUIRE_ARTIFACTS").map_or(true, |v| v != "1"),
                "ZAC_REQUIRE_ARTIFACTS=1 but PJRT runtime failed to load: {e}"
            );
            eprintln!("skipping PJRT workload test (run `make artifacts`): {e}");
            return None;
        }
    };
    Some(Suite::build(rt, 42, SuiteBudget::quick()).expect("suite build"))
}

#[test]
fn workloads_train_above_chance_and_quality_degrades_gracefully() {
    let Some(s) = suite() else { return };
    // Clean-data sanity: everything learns something.
    for (&acc, name) in s
        .zoo_clean_acc
        .iter()
        .zip(std::iter::repeat("zoo"))
        .chain([(&s.resnet_clean_acc, "resnet")])
    {
        assert!(acc > 0.15, "{name} clean accuracy {acc} at chance (0.1)");
    }
    assert!(s.svm_clean_acc > 0.5, "svm {}", s.svm_clean_acc);
    assert!(s.eigen_clean_acc > 0.5, "eigen {}", s.eigen_clean_acc);
    assert!(s.quant_clean_ssim[0] > 0.5);

    // Exact scheme ⇒ quality exactly 1.0 for every workload.
    for kind in Kind::all() {
        let r = s.eval(&CodecSpec::named("BDE"), kind).unwrap();
        assert!(
            (r.quality - 1.0).abs() < 1e-9,
            "{}: exact scheme must give quality 1.0, got {}",
            kind.label(),
            r.quality
        );
    }

    // Approximation: quality stays in [0, ~1.2] and the conservative
    // L90 config stays close to 1.
    for kind in Kind::all() {
        let r90 = s.eval(&CodecSpec::zac(90), kind).unwrap();
        assert!(
            r90.quality > 0.6,
            "{}: L90 quality {} too low",
            kind.label(),
            r90.quality
        );
        let r70 = s.eval(&CodecSpec::zac_full(70, 2, 0), kind).unwrap();
        assert!(
            (0.0..=1.5).contains(&r70.quality),
            "{}: L70T16 quality {} out of range",
            kind.label(),
            r70.quality
        );
        // Aggressive configs never *increase* the trace energy vs L90.
        assert!(
            r70.run.counts.termination_ones <= r90.run.counts.termination_ones
        );
    }
}

#[test]
fn fault_injection_costs_quality_and_fault_aware_training_recovers() {
    let Some(s) = suite() else { return };
    let spec = CodecSpec::zac(90);
    // Injection must cost measurable quality vs the perfect channel.
    let clean = s.eval(&spec, Kind::ResNet).unwrap();
    let faulty = s
        .eval_under(&spec, &FaultSpec::voltage(1000), Kind::ResNet)
        .unwrap();
    assert!(faulty.run.faults.injected_bits > 0, "no flips injected");
    assert_eq!(
        faulty.run.counts, clean.run.counts,
        "energy must be fault-invariant"
    );
    assert!(
        faulty.quality <= clean.quality + 0.05,
        "faults increased quality: {} vs {}",
        faulty.quality,
        clean.quality
    );
    // The paper-shaped mismatch experiment: training on the faulty
    // pipeline (fault-aware) must not do worse than meeting the faults
    // cold (fault-oblivious), minus training noise.
    let (oblivious, aware) = s
        .resnet_fault_mismatch(&spec, &FaultSpec::voltage(1000))
        .unwrap();
    assert!((0.0..=1.5).contains(&oblivious.quality));
    assert!((0.0..=1.5).contains(&aware.quality));
    assert!(
        aware.quality >= oblivious.quality - 0.15,
        "fault-aware training collapsed: aware {} vs oblivious {}",
        aware.quality,
        oblivious.quality
    );
}

#[test]
fn weight_approximation_keeps_model_usable_at_high_limits() {
    let Some(s) = suite() else { return };
    let r = s
        .resnet_with_approx_weights(&CodecSpec::zac_weights(70), None)
        .unwrap();
    // Sign+exponent are pinned, so a 70% weight limit must not destroy
    // the model.
    assert!(
        r.quality > 0.5,
        "weight-approx L70 quality {} too low",
        r.quality
    );
}
