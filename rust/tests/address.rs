//! Address-mapping layer properties: every policy is conservative
//! (de-interleave ∘ interleave == identity), `RoundRobin` stays
//! bit-identical to the v1 array, and `LocalitySteer` actually raises
//! the per-channel `DataTable` hit rate on the image-like trace.

use std::sync::Arc;

use zac_dest::channel::CHIPS;
use zac_dest::coordinator::simulate_lines;
use zac_dest::encoding::{CodecSpec, EncodeStats, ZacConfig};
use zac_dest::session::{Execution, Session, Trace, TrafficClass};
use zac_dest::system::{synthetic_trace as image_like, AddressSpec, ChannelArray};
use zac_dest::trace::{bytes_to_chip_words, ChipWords};
use zac_dest::util::prop;

fn policies() -> Vec<AddressSpec> {
    vec![
        AddressSpec::round_robin(),
        AddressSpec::capacity(vec![2, 1]),
        AddressSpec::capacity(vec![1, 3, 2]),
        AddressSpec::steer_with(8),
        AddressSpec::steer(),
    ]
}

fn run_with(
    spec: &CodecSpec,
    address: &AddressSpec,
    channels: usize,
    bytes: &[u8],
) -> zac_dest::session::RunReport {
    Session::builder()
        .codec(spec.clone())
        .channels(channels)
        .address(address.clone())
        .execution(Execution::Sharded)
        .traffic(TrafficClass::Approximate)
        .build()
        .unwrap()
        .run(&Trace::from_bytes(bytes.to_vec()))
        .unwrap()
}

#[test]
fn prop_every_address_map_is_conservative() {
    // Interleave + de-interleave must be the identity for an exact
    // scheme — decoded bytes equal the trace bit-for-bit — and no line
    // may be lost or duplicated, for every policy × 1/2/4 shards,
    // including partial tail chunks.
    let policies = policies();
    prop::check(
        "address maps conserve the stream",
        108,
        |r| {
            let nlines = r.range(1, 48);
            let shards = [1u64, 2, 4][r.range(0, 3)];
            let which = r.range(0, 5) as u64;
            vec![nlines as u64, shards, which, r.next_u64()]
        },
        |v| {
            let nlines = (v[0] as usize).clamp(1, 64);
            let shards = (v[1] as usize).clamp(1, 4);
            let address = &policies[(v[2] as usize) % policies.len()];
            let bytes = image_like(nlines * 64 - 16, v[3]);
            let report = run_with(&CodecSpec::named("BDE"), address, shards, &bytes);
            if report.bytes != bytes {
                return Err(format!(
                    "{} x{shards}: decoded bytes diverge from the trace",
                    address.label()
                ));
            }
            let total: usize = report.shards.iter().map(|s| s.lines).sum();
            if total != nlines {
                return Err(format!(
                    "{} x{shards}: {total} shard lines for {nlines} pushed",
                    address.label()
                ));
            }
            if report.stats.total() != (nlines * CHIPS) as u64 {
                return Err(format!("{} x{shards}: stats lost transfers", address.label()));
            }
            if report.counts.transfers != (nlines * CHIPS) as u64 {
                return Err(format!("{} x{shards}: counts lost transfers", address.label()));
            }
            Ok(())
        },
    );
}

#[test]
fn termination_energy_is_placement_invariant_for_stateless_codecs() {
    // ORG drives every word's true bits exactly once, so total
    // termination ones and transfers cannot depend on which shard served
    // which line — a sharper conservation property than byte identity.
    let bytes = image_like(300 * 64, 51);
    let reference = run_with(
        &CodecSpec::named("ORG"),
        &AddressSpec::round_robin(),
        2,
        &bytes,
    );
    for address in policies() {
        for shards in [1usize, 2, 4] {
            let report = run_with(&CodecSpec::named("ORG"), &address, shards, &bytes);
            let label = format!("{} x{shards}", address.label());
            assert_eq!(report.bytes, bytes, "{label}");
            assert_eq!(
                report.counts.termination_ones, reference.counts.termination_ones,
                "{label}"
            );
            assert_eq!(report.counts.transfers, reference.counts.transfers, "{label}");
        }
    }
}

#[test]
fn round_robin_spec_is_bit_identical_to_the_v1_array() {
    // The explicit round_robin AddressSpec must reproduce the v1
    // hard-coded interleaving exactly: same bytes, stats and counts as
    // (a) the legacy push_line array and (b) independent single-channel
    // runs over the interleaved subsequences.
    let bytes = image_like(310 * 64 + 24, 53);
    let lines = bytes_to_chip_words(&bytes);
    for spec in [
        CodecSpec::named("BDE"),
        CodecSpec::zac(80),
        CodecSpec::zac_full(75, 1, 1),
    ] {
        let cfg = spec.to_config().unwrap();
        for shards in [1usize, 2, 4] {
            let report = run_with(&spec, &AddressSpec::round_robin(), shards, &bytes);
            let legacy = ChannelArray::run(&cfg, shards, &lines, true, bytes.len());
            let label = format!("{} x{shards}", spec.label());
            assert_eq!(report.bytes, legacy.bytes, "{label}");
            assert_eq!(report.counts, legacy.counts, "{label}");
            assert_eq!(report.stats, legacy.stats, "{label}");

            let mut stats = EncodeStats::default();
            for s in 0..shards {
                let sub: Vec<ChipWords> =
                    lines.iter().skip(s).step_by(shards).copied().collect();
                let r = simulate_lines(&cfg, &sub, true, sub.len() * 64);
                assert_eq!(report.shards[s].stats, r.stats, "{label} shard {s}");
                assert_eq!(report.shards[s].counts, r.counts, "{label} shard {s}");
                stats.merge(&r.stats);
            }
            assert_eq!(report.stats, stats, "{label}");
        }
    }
}

#[test]
fn locality_steer_raises_the_table_hit_rate_on_the_image_trace() {
    // Acceptance: steering routes whole pages (distance-1 neighbors) to
    // one channel, so each channel's DataTable sees maximally similar
    // history; round-robin hands every channel a strided (distance-N)
    // subsequence. Pinned seed, 4 channels, ZAC L75.
    let bytes = image_like(1 << 18, 31);
    let spec = CodecSpec::zac(75);
    let rr = run_with(&spec, &AddressSpec::round_robin(), 4, &bytes);
    let steer = run_with(&spec, &AddressSpec::steer(), 4, &bytes);
    assert!(
        steer.stats.table_hit_rate() > rr.stats.table_hit_rate(),
        "steer hit rate {:.4} must beat round-robin {:.4}",
        steer.stats.table_hit_rate(),
        rr.stats.table_hit_rate()
    );
    assert!(
        steer.counts.termination_ones <= rr.counts.termination_ones,
        "steer termination {} must not exceed round-robin {}",
        steer.counts.termination_ones,
        rr.counts.termination_ones
    );
    // Both placements cover the whole stream.
    assert_eq!(
        steer.shards.iter().map(|s| s.lines).sum::<usize>(),
        bytes.len() / 64
    );
    assert!(steer.load_imbalance() >= 1.0);
}

#[test]
fn recorded_inverse_reassembles_mixed_criticality_streams() {
    // The route-log inverse must survive per-line approx flags and
    // unequal shard loads: stream through the steering array line by
    // line with alternating criticality and an exact scheme — the
    // receiver must reassemble the trace exactly.
    let bytes = image_like(137 * 64, 57);
    let store: Arc<[ChipWords]> = bytes_to_chip_words(&bytes).into();
    let cfg = ZacConfig::zac(80);
    let sets = (0..3)
        .map(|_| {
            (0..CHIPS)
                .map(|_| zac_dest::encoding::Codec::from_config(&cfg))
                .collect()
        })
        .collect();
    let mut array = ChannelArray::with_codec_sets_faults_and_address(
        sets,
        256,
        &zac_dest::faults::FaultSpec::perfect(),
        &AddressSpec::steer_with(4),
    );
    for (i, line) in store.iter().enumerate() {
        // ZAC approximates only approx lines; critical lines are exact.
        // With limit 80 on a slow walk both decode exactly only for
        // critical lines, so flip criticality per line and check the
        // critical subset round-trips exactly in trace order.
        array.push_line(*line, i % 2 == 0);
    }
    let out = array.finish(bytes.len());
    let decoded = bytes_to_chip_words(&out.bytes);
    assert_eq!(decoded.len(), store.len());
    for (i, (got, want)) in decoded.iter().zip(store.iter()).enumerate() {
        if i % 2 == 1 {
            assert_eq!(got, want, "critical line {i} must round-trip in place");
        }
    }
    let total: usize = out.shards.iter().map(|s| s.lines).sum();
    assert_eq!(total, store.len());
}

#[test]
fn capacity_weights_shape_shard_loads_through_the_session() {
    let bytes = image_like(600 * 64, 59);
    let report = run_with(
        &CodecSpec::named("BDE"),
        &AddressSpec::capacity(vec![1, 3, 2]),
        3,
        &bytes,
    );
    assert_eq!(report.bytes, bytes);
    assert_eq!(
        report.shards.iter().map(|s| s.lines).collect::<Vec<_>>(),
        vec![100, 300, 200]
    );
    assert!((report.load_imbalance() - 1.5).abs() < 1e-12);
}
