//! Backend bit-identity pins: every SIMD backend this host can run
//! must return exactly what the scalar reference returns — hit index,
//! stored entry, distance, and lowest-index tie-breaks — across table
//! capacities, fill levels, resets and FIFO wraparound, for the single,
//! batch and exact-match (`contains`) searches. A session built with an
//! explicit backend must produce figures identical to the scalar one.
//!
//! CI runs the whole suite twice (`ZAC_SIMD=scalar` and `ZAC_SIMD=auto`)
//! so the dispatched default is exercised end-to-end on both paths.

use zac_dest::encoding::{simd, Backend, CodecSpec, SimdPref};
use zac_dest::session::{Execution, Session, Trace, TrafficClass};
use zac_dest::util::rng::seeded_rng;

/// Naive linear-scan argmin with lowest-index ties — the oracle.
fn naive_argmin(entries: &[u64], q: u64) -> (usize, u32) {
    let (mut bi, mut bd) = (0usize, u32::MAX);
    for (i, &e) in entries.iter().enumerate() {
        let d = (e ^ q).count_ones();
        if d < bd {
            bd = d;
            bi = i;
        }
    }
    (bi, bd)
}

/// Tie-heavy query mix: zeros, all-ones, one-bit perturbations of live
/// entries, and uniform noise.
fn query(r: &mut zac_dest::util::rng::Rng, live: &[u64]) -> u64 {
    match r.below(4) {
        0 => 0,
        1 => u64::MAX,
        2 => live[r.below(live.len() as u64) as usize] ^ (1u64 << r.below(64)),
        _ => r.next_u64(),
    }
}

#[test]
fn every_backend_matches_scalar_across_fills_resets_and_wraparound() {
    let backends = simd::available_backends();
    assert_eq!(backends[0], Backend::Scalar);
    for &backend in &backends {
        let mut r = seeded_rng(0xCA3);
        // Capacities span one 64-slot plane group, several groups, and
        // the old broken ≥ 256 index range.
        for cap in [1usize, 3, 8, 63, 64, 65, 127, 257] {
            let mut t = zac_dest::encoding::DataTable::with_backend(cap, backend);
            assert_eq!(t.backend(), backend);
            assert!(t.most_similar_sliced(7).is_none());
            // Two full FIFO laps plus a partial third (wraparound), with
            // a mid-life reset + refill.
            for phase in 0..2 {
                if phase == 1 {
                    t.reset();
                    assert!(t.most_similar_sliced(7).is_none());
                }
                for _ in 0..cap.min(96) * 2 + 5 {
                    t.push(r.next_u64() & 0x3FFF); // small domain => ties
                    for _ in 0..6 {
                        let q = query(&mut r, t.snapshot());
                        let want = naive_argmin(t.snapshot(), q);
                        let hit = t.most_similar_sliced(q).unwrap();
                        assert_eq!(
                            (hit.index, hit.distance),
                            want,
                            "{} cap {cap} q {q:#x}",
                            backend.label()
                        );
                        assert_eq!(hit.entry, t.snapshot()[want.0], "{}", backend.label());
                        assert_eq!(
                            t.contains(q),
                            t.snapshot().contains(&q),
                            "{} cap {cap} q {q:#x}",
                            backend.label()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn batch_search_is_bit_identical_on_every_backend() {
    let mut r = seeded_rng(0xBA7C);
    let queries: Vec<u64> = (0..512).map(|_| r.next_u64() & 0xFFF).collect();
    for cap in [5usize, 64, 257] {
        let mut tables: Vec<_> = simd::available_backends()
            .into_iter()
            .map(|b| zac_dest::encoding::DataTable::with_backend(cap, b))
            .collect();
        for _ in 0..cap + cap / 2 {
            let w = r.next_u64() & 0xFFF;
            for t in tables.iter_mut() {
                t.push(w);
            }
        }
        let mut want = Vec::new();
        tables[0].most_similar_batch(&queries, &mut want);
        let mut hits = Vec::new();
        for t in &tables[1..] {
            t.most_similar_batch(&queries, &mut hits);
            assert_eq!(hits, want, "{} cap {cap}", t.backend().label());
        }
    }
}

#[test]
fn sessions_report_identical_figures_on_every_backend() {
    // End-to-end pin: an explicit-backend session must reproduce the
    // scalar session's RunReport exactly — reconstruction bytes, energy
    // counts and outcome statistics — on batch and sharded executions.
    let trace = Trace::from_bytes(zac_dest::system::synthetic_trace(4096, 9));
    let run = |pref: SimdPref, exec: Execution, channels: usize| {
        Session::builder()
            .codec(CodecSpec::zac(80))
            .channels(channels)
            .execution(exec)
            .traffic(TrafficClass::Approximate)
            .simd(pref)
            .build()
            .unwrap()
            .run(&trace)
            .unwrap()
    };
    for (exec, channels) in [(Execution::Batch, 1), (Execution::Sharded, 2)] {
        let scalar = run(SimdPref::Scalar, exec, channels);
        for backend in simd::available_backends() {
            let pref = SimdPref::parse(backend.label()).unwrap();
            let report = run(pref, exec, channels);
            let tag = format!("{} {exec:?}", backend.label());
            assert_eq!(report.bytes, scalar.bytes, "{tag}");
            assert_eq!(report.counts, scalar.counts, "{tag}");
            assert_eq!(report.stats, scalar.stats, "{tag}");
        }
    }
}

#[test]
fn builder_override_beats_env_and_unavailable_backend_fails_build() {
    let session = Session::builder()
        .codec(CodecSpec::named("BDE"))
        .simd(SimdPref::Scalar)
        .build()
        .unwrap();
    assert_eq!(session.simd_backend(), Backend::Scalar);
    // An explicit backend the host lacks is a build()-time error, not a
    // silent fallback.
    for (avail, pref) in [
        (simd::avx2_available(), SimdPref::Avx2),
        (simd::neon_available(), SimdPref::Neon),
    ] {
        if !avail {
            let err = Session::builder()
                .codec(CodecSpec::named("BDE"))
                .simd(pref)
                .build()
                .unwrap_err()
                .to_string();
            assert!(err.contains(pref.label()), "{err}");
        }
    }
}
