//! Fault-layer acceptance: `FaultSpec::perfect()` is pinned
//! bit-identical to the historical no-fault path across the codec
//! matrix × Batch/Pipelined/Sharded execution, and fixed-seed injection
//! is byte-for-byte reproducible at every channel count.

use zac_dest::coordinator::simulate_lines;
use zac_dest::encoding::CodecSpec;
use zac_dest::faults::FaultSpec;
use zac_dest::session::{Execution, Session, Trace, TrafficClass};
use zac_dest::system::{synthetic_trace as image_like, ChannelArray};
use zac_dest::trace::bytes_to_chip_words;
use zac_dest::util::prop;

/// The codec matrix the fault acceptance pins (same shape as the v2
/// acceptance matrix).
fn spec_matrix() -> Vec<CodecSpec> {
    vec![
        CodecSpec::named("ORG"),
        CodecSpec::named("DBI"),
        CodecSpec::named("BDE_ORG"),
        CodecSpec::named("BDE"),
        CodecSpec::zac(80),
        CodecSpec::zac_full(75, 2, 1),
        CodecSpec::zac_weights(60),
    ]
}

fn run(
    spec: &CodecSpec,
    faults: FaultSpec,
    exec: Execution,
    channels: usize,
    trace: &Trace,
) -> zac_dest::session::RunReport {
    Session::builder()
        .codec(spec.clone())
        .channels(channels)
        .execution(exec)
        .traffic(TrafficClass::Approximate)
        .faults(faults)
        .build()
        .unwrap()
        .run(trace)
        .unwrap()
}

#[test]
fn perfect_spec_is_bit_identical_to_the_no_fault_path_across_the_matrix() {
    // Acceptance: FaultSpec::perfect() == today's no-fault path for
    // every spec in the matrix under Batch, Pipelined and Sharded
    // execution (bytes, energy counts, encode stats).
    let bytes = image_like(300 * 64 + 32, 51);
    let lines = bytes_to_chip_words(&bytes);
    let trace = Trace::from_bytes(bytes.clone());
    for spec in spec_matrix() {
        let cfg = spec.to_config().unwrap();
        let legacy = simulate_lines(&cfg, &lines, true, bytes.len());
        for exec in [Execution::Batch, Execution::Pipelined, Execution::Sharded] {
            let report = run(&spec, FaultSpec::perfect(), exec, 1, &trace);
            assert_eq!(report.bytes, legacy.bytes, "{} {exec:?}", spec.label());
            assert_eq!(report.counts, legacy.counts, "{} {exec:?}", spec.label());
            assert_eq!(report.stats, legacy.stats, "{} {exec:?}", spec.label());
            assert_eq!(report.faults.injected_bits, 0, "{}", spec.label());
        }
        for channels in [2usize, 4] {
            let report = run(&spec, FaultSpec::perfect(), Execution::Sharded, channels, &trace);
            let legacy_arr = ChannelArray::run(&cfg, channels, &lines, true, bytes.len());
            assert_eq!(report.bytes, legacy_arr.bytes, "{} x{channels}", spec.label());
            assert_eq!(report.counts, legacy_arr.counts, "{} x{channels}", spec.label());
            assert_eq!(report.stats, legacy_arr.stats, "{} x{channels}", spec.label());
        }
    }
}

#[test]
fn prop_perfect_spec_equals_no_fault_path_on_random_traces() {
    let matrix = spec_matrix();
    prop::check(
        "FaultSpec::perfect() ≡ no-fault path",
        108,
        |r| {
            let nlines = r.range(1, 40);
            let which = r.range(0, 7);
            let channels = [1u64, 2, 4][r.range(0, 3)];
            vec![nlines as u64, which as u64, channels, r.next_u64()]
        },
        |v| {
            let nlines = (v[0] as usize).clamp(1, 64);
            let spec = &matrix[(v[1] as usize) % matrix.len()];
            let channels = (v[2] as usize).clamp(1, 4);
            let bytes = image_like(nlines * 64, v[3]);
            let lines = bytes_to_chip_words(&bytes);
            let cfg = spec.to_config().unwrap();
            let legacy = ChannelArray::run(&cfg, channels, &lines, true, bytes.len());
            let report = run(
                spec,
                FaultSpec::perfect(),
                Execution::Sharded,
                channels,
                &Trace::from_bytes(bytes),
            );
            if report.bytes != legacy.bytes {
                return Err(format!("{} x{channels}: bytes diverge", spec.label()));
            }
            if report.counts != legacy.counts {
                return Err(format!("{} x{channels}: counts diverge", spec.label()));
            }
            if report.stats != legacy.stats {
                return Err(format!("{} x{channels}: stats diverge", spec.label()));
            }
            if report.faults.injected_bits != 0 {
                return Err("perfect channel injected flips".into());
            }
            Ok(())
        },
    );
}

#[test]
fn fixed_seed_injection_is_reproducible_at_every_channel_count() {
    // Acceptance: a fixed-seed injection run is byte-for-byte
    // reproducible across 1/2/4 channels.
    let bytes = image_like(200 * 64, 53);
    let trace = Trace::from_bytes(bytes.clone());
    let faults = FaultSpec::voltage(1000).with_seed(7);
    for channels in [1usize, 2, 4] {
        let a = run(&CodecSpec::zac(80), faults, Execution::Sharded, channels, &trace);
        let b = run(&CodecSpec::zac(80), faults, Execution::Sharded, channels, &trace);
        assert_eq!(a.bytes, b.bytes, "x{channels}: bytes not reproducible");
        assert_eq!(a.counts, b.counts, "x{channels}");
        assert_eq!(a.stats, b.stats, "x{channels}");
        assert_eq!(a.faults, b.faults, "x{channels}");
        assert!(
            a.faults.injected_bits > 0,
            "x{channels}: no flips at 1e-3-binned voltage"
        );
        assert_ne!(a.bytes, bytes, "x{channels}: faults left the stream exact");
        // A different seed produces a different corruption pattern.
        let c = run(
            &CodecSpec::zac(80),
            faults.with_seed(8),
            Execution::Sharded,
            channels,
            &trace,
        );
        assert_ne!(a.bytes, c.bytes, "x{channels}: seed had no effect");
    }
}

#[test]
fn single_channel_executions_agree_under_injection() {
    // Batch, Pipelined and 1-shard Sharded all drive lane (shard 0,
    // chip j) over the same word order, so one fixed-seed fault spec
    // must corrupt all three identically.
    let trace = Trace::from_bytes(image_like(150 * 64, 55));
    let faults = FaultSpec::uniform(1e-3).with_seed(11);
    let batch = run(&CodecSpec::named("BDE"), faults, Execution::Batch, 1, &trace);
    let piped = run(&CodecSpec::named("BDE"), faults, Execution::Pipelined, 1, &trace);
    let sharded = run(&CodecSpec::named("BDE"), faults, Execution::Sharded, 1, &trace);
    assert!(batch.faults.injected_bits > 0);
    assert_eq!(batch.bytes, piped.bytes);
    assert_eq!(batch.bytes, sharded.bytes);
    assert_eq!(batch.faults, piped.faults);
    assert_eq!(batch.faults, sharded.faults);
}

#[test]
fn injection_never_changes_the_energy_accounting() {
    // Faults fire after transmit: the paper's energy axis is invariant,
    // only the quality axis moves.
    let trace = Trace::from_bytes(image_like(128 * 64, 57));
    for spec in spec_matrix() {
        let clean = run(&spec, FaultSpec::perfect(), Execution::Batch, 1, &trace);
        let faulty = run(
            &spec,
            FaultSpec::uniform(5e-3).with_seed(3),
            Execution::Batch,
            1,
            &trace,
        );
        assert_eq!(clean.counts, faulty.counts, "{}", spec.label());
        assert_eq!(clean.stats, faulty.stats, "{}", spec.label());
        assert!(faulty.faults.injected_bits > 0, "{}", spec.label());
        // Exact schemes have zero end-to-end error on a perfect channel,
        // and any surfaced flip shows up in the observed count. (For
        // ZAC the clean baseline already carries approximation error,
        // so only the injection count is asserted above.)
        if matches!(spec.scheme.as_str(), "ORG" | "DBI" | "BDE" | "BDE_ORG") {
            assert_eq!(clean.faults.observed_error_bits, 0, "{}", spec.label());
            assert!(
                faulty.faults.observed_error_bits > 0,
                "{}: injected flips never surfaced",
                spec.label()
            );
        }
    }
}

#[test]
fn charge_loss_asymmetry_shows_on_polarized_streams() {
    // ORG is a passthrough, so injected flips surface 1:1. An all-ones
    // stream only suffers 1->0 flips, an all-zero stream only 0->1;
    // with the default 0.75 bias the former must see roughly 3x more.
    let n = 64 * 1024;
    let faults = FaultSpec::uniform(5e-3).with_seed(13);
    let ones = run(
        &CodecSpec::named("ORG"),
        faults,
        Execution::Batch,
        1,
        &Trace::from_bytes(vec![0xFF; n]),
    );
    let zeros = run(
        &CodecSpec::named("ORG"),
        faults,
        Execution::Batch,
        1,
        &Trace::from_bytes(vec![0x00; n]),
    );
    assert!(ones.faults.injected_bits > 0);
    assert!(zeros.faults.injected_bits > 0);
    let ratio = ones.faults.injected_bits as f64 / zeros.faults.injected_bits as f64;
    assert!(
        (2.0..4.5).contains(&ratio),
        "1->0 / 0->1 ratio {ratio} far from the 3x charge-loss bias"
    );
}

#[test]
fn critical_traffic_is_untouched_at_any_channel_count() {
    let bytes = image_like(100 * 64, 59);
    let trace = Trace::from_bytes(bytes.clone());
    for channels in [1usize, 3] {
        let report = Session::builder()
            .codec(CodecSpec::zac(70))
            .channels(channels)
            .traffic(TrafficClass::Critical)
            .faults(FaultSpec::uniform(0.25).with_seed(1))
            .build()
            .unwrap()
            .run(&trace)
            .unwrap();
        assert_eq!(report.bytes, bytes, "x{channels}");
        assert_eq!(report.faults.injected_bits, 0, "x{channels}");
        assert_eq!(report.faults.observed_error_bits, 0, "x{channels}");
    }
}

#[test]
fn faulty_zac_stays_decodable_and_bounded_under_heavy_injection() {
    // Corrupted one-hot indices and xor payloads must decode to *some*
    // word (total decoders, no panics) even at absurd BERs, and the
    // stream shape survives: same length, deterministic result.
    let bytes = image_like(200 * 64, 61);
    let trace = Trace::from_bytes(bytes.clone());
    for spec in spec_matrix() {
        let report = run(
            &spec,
            FaultSpec::uniform(0.05).with_seed(17),
            Execution::Sharded,
            2,
            &trace,
        );
        assert_eq!(report.bytes.len(), bytes.len(), "{}", spec.label());
        assert!(report.faults.injected_bits > 0, "{}", spec.label());
    }
}
