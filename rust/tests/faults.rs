//! Fault-layer acceptance: `FaultSpec::perfect()` is pinned
//! bit-identical to the historical no-fault path across the codec
//! matrix × Batch/Pipelined/Sharded execution, and fixed-seed injection
//! is byte-for-byte reproducible at every channel count.

use zac_dest::coordinator::simulate_lines;
use zac_dest::encoding::CodecSpec;
use zac_dest::faults::{FaultSpec, MramBin};
use zac_dest::session::{Execution, Session, Trace, TrafficClass};
use zac_dest::system::{synthetic_trace as image_like, ChannelArray};
use zac_dest::trace::bytes_to_chip_words;
use zac_dest::util::prop;

/// The codec matrix the fault acceptance pins (same shape as the v2
/// acceptance matrix).
fn spec_matrix() -> Vec<CodecSpec> {
    vec![
        CodecSpec::named("ORG"),
        CodecSpec::named("DBI"),
        CodecSpec::named("BDE_ORG"),
        CodecSpec::named("BDE"),
        CodecSpec::zac(80),
        CodecSpec::zac_full(75, 2, 1),
        CodecSpec::zac_weights(60),
    ]
}

fn run(
    spec: &CodecSpec,
    faults: FaultSpec,
    exec: Execution,
    channels: usize,
    trace: &Trace,
) -> zac_dest::session::RunReport {
    Session::builder()
        .codec(spec.clone())
        .channels(channels)
        .execution(exec)
        .traffic(TrafficClass::Approximate)
        .faults(faults)
        .build()
        .unwrap()
        .run(trace)
        .unwrap()
}

#[test]
fn perfect_spec_is_bit_identical_to_the_no_fault_path_across_the_matrix() {
    // Acceptance: FaultSpec::perfect() == today's no-fault path for
    // every spec in the matrix under Batch, Pipelined and Sharded
    // execution (bytes, energy counts, encode stats).
    let bytes = image_like(300 * 64 + 32, 51);
    let lines = bytes_to_chip_words(&bytes);
    let trace = Trace::from_bytes(bytes.clone());
    for spec in spec_matrix() {
        let cfg = spec.to_config().unwrap();
        let legacy = simulate_lines(&cfg, &lines, true, bytes.len());
        for exec in [Execution::Batch, Execution::Pipelined, Execution::Sharded] {
            let report = run(&spec, FaultSpec::perfect(), exec, 1, &trace);
            assert_eq!(report.bytes, legacy.bytes, "{} {exec:?}", spec.label());
            assert_eq!(report.counts, legacy.counts, "{} {exec:?}", spec.label());
            assert_eq!(report.stats, legacy.stats, "{} {exec:?}", spec.label());
            assert_eq!(report.faults.injected_bits, 0, "{}", spec.label());
        }
        for channels in [2usize, 4] {
            let report = run(&spec, FaultSpec::perfect(), Execution::Sharded, channels, &trace);
            let legacy_arr = ChannelArray::run(&cfg, channels, &lines, true, bytes.len());
            assert_eq!(report.bytes, legacy_arr.bytes, "{} x{channels}", spec.label());
            assert_eq!(report.counts, legacy_arr.counts, "{} x{channels}", spec.label());
            assert_eq!(report.stats, legacy_arr.stats, "{} x{channels}", spec.label());
        }
    }
}

#[test]
fn prop_perfect_spec_equals_no_fault_path_on_random_traces() {
    let matrix = spec_matrix();
    prop::check(
        "FaultSpec::perfect() ≡ no-fault path",
        108,
        |r| {
            let nlines = r.range(1, 40);
            let which = r.range(0, 7);
            let channels = [1u64, 2, 4][r.range(0, 3)];
            vec![nlines as u64, which as u64, channels, r.next_u64()]
        },
        |v| {
            let nlines = (v[0] as usize).clamp(1, 64);
            let spec = &matrix[(v[1] as usize) % matrix.len()];
            let channels = (v[2] as usize).clamp(1, 4);
            let bytes = image_like(nlines * 64, v[3]);
            let lines = bytes_to_chip_words(&bytes);
            let cfg = spec.to_config().unwrap();
            let legacy = ChannelArray::run(&cfg, channels, &lines, true, bytes.len());
            let report = run(
                spec,
                FaultSpec::perfect(),
                Execution::Sharded,
                channels,
                &Trace::from_bytes(bytes),
            );
            if report.bytes != legacy.bytes {
                return Err(format!("{} x{channels}: bytes diverge", spec.label()));
            }
            if report.counts != legacy.counts {
                return Err(format!("{} x{channels}: counts diverge", spec.label()));
            }
            if report.stats != legacy.stats {
                return Err(format!("{} x{channels}: stats diverge", spec.label()));
            }
            if report.faults.injected_bits != 0 {
                return Err("perfect channel injected flips".into());
            }
            Ok(())
        },
    );
}

#[test]
fn fixed_seed_injection_is_reproducible_at_every_channel_count() {
    // Acceptance: a fixed-seed injection run is byte-for-byte
    // reproducible across 1/2/4 channels.
    let bytes = image_like(200 * 64, 53);
    let trace = Trace::from_bytes(bytes.clone());
    let faults = FaultSpec::voltage(1000).with_seed(7);
    for channels in [1usize, 2, 4] {
        let a = run(&CodecSpec::zac(80), faults, Execution::Sharded, channels, &trace);
        let b = run(&CodecSpec::zac(80), faults, Execution::Sharded, channels, &trace);
        assert_eq!(a.bytes, b.bytes, "x{channels}: bytes not reproducible");
        assert_eq!(a.counts, b.counts, "x{channels}");
        assert_eq!(a.stats, b.stats, "x{channels}");
        assert_eq!(a.faults, b.faults, "x{channels}");
        assert!(
            a.faults.injected_bits > 0,
            "x{channels}: no flips at 1e-3-binned voltage"
        );
        assert_ne!(a.bytes, bytes, "x{channels}: faults left the stream exact");
        // A different seed produces a different corruption pattern.
        let c = run(
            &CodecSpec::zac(80),
            faults.with_seed(8),
            Execution::Sharded,
            channels,
            &trace,
        );
        assert_ne!(a.bytes, c.bytes, "x{channels}: seed had no effect");
    }
}

#[test]
fn single_channel_executions_agree_under_injection() {
    // Batch, Pipelined and 1-shard Sharded all drive lane (shard 0,
    // chip j) over the same word order, so one fixed-seed fault spec
    // must corrupt all three identically.
    let trace = Trace::from_bytes(image_like(150 * 64, 55));
    let faults = FaultSpec::uniform(1e-3).with_seed(11);
    let batch = run(&CodecSpec::named("BDE"), faults, Execution::Batch, 1, &trace);
    let piped = run(&CodecSpec::named("BDE"), faults, Execution::Pipelined, 1, &trace);
    let sharded = run(&CodecSpec::named("BDE"), faults, Execution::Sharded, 1, &trace);
    assert!(batch.faults.injected_bits > 0);
    assert_eq!(batch.bytes, piped.bytes);
    assert_eq!(batch.bytes, sharded.bytes);
    assert_eq!(batch.faults, piped.faults);
    assert_eq!(batch.faults, sharded.faults);
}

#[test]
fn injection_never_changes_the_energy_accounting() {
    // Faults fire after transmit: the paper's energy axis is invariant,
    // only the quality axis moves.
    let trace = Trace::from_bytes(image_like(128 * 64, 57));
    for spec in spec_matrix() {
        let clean = run(&spec, FaultSpec::perfect(), Execution::Batch, 1, &trace);
        let faulty = run(
            &spec,
            FaultSpec::uniform(5e-3).with_seed(3),
            Execution::Batch,
            1,
            &trace,
        );
        assert_eq!(clean.counts, faulty.counts, "{}", spec.label());
        assert_eq!(clean.stats, faulty.stats, "{}", spec.label());
        assert!(faulty.faults.injected_bits > 0, "{}", spec.label());
        // Exact schemes have zero end-to-end error on a perfect channel,
        // and any surfaced flip shows up in the observed count. (For
        // ZAC the clean baseline already carries approximation error,
        // so only the injection count is asserted above.)
        if matches!(spec.scheme.as_str(), "ORG" | "DBI" | "BDE" | "BDE_ORG") {
            assert_eq!(clean.faults.observed_error_bits, 0, "{}", spec.label());
            assert!(
                faulty.faults.observed_error_bits > 0,
                "{}: injected flips never surfaced",
                spec.label()
            );
        }
    }
}

#[test]
fn charge_loss_asymmetry_shows_on_polarized_streams() {
    // ORG is a passthrough, so injected flips surface 1:1. An all-ones
    // stream only suffers 1->0 flips, an all-zero stream only 0->1;
    // with the default 0.75 bias the former must see roughly 3x more.
    let n = 64 * 1024;
    let faults = FaultSpec::uniform(5e-3).with_seed(13);
    let ones = run(
        &CodecSpec::named("ORG"),
        faults,
        Execution::Batch,
        1,
        &Trace::from_bytes(vec![0xFF; n]),
    );
    let zeros = run(
        &CodecSpec::named("ORG"),
        faults,
        Execution::Batch,
        1,
        &Trace::from_bytes(vec![0x00; n]),
    );
    assert!(ones.faults.injected_bits > 0);
    assert!(zeros.faults.injected_bits > 0);
    let ratio = ones.faults.injected_bits as f64 / zeros.faults.injected_bits as f64;
    assert!(
        (2.0..4.5).contains(&ratio),
        "1->0 / 0->1 ratio {ratio} far from the 3x charge-loss bias"
    );
}

#[test]
fn critical_traffic_is_untouched_at_any_channel_count() {
    let bytes = image_like(100 * 64, 59);
    let trace = Trace::from_bytes(bytes.clone());
    for channels in [1usize, 3] {
        let report = Session::builder()
            .codec(CodecSpec::zac(70))
            .channels(channels)
            .traffic(TrafficClass::Critical)
            .faults(FaultSpec::uniform(0.25).with_seed(1))
            .build()
            .unwrap()
            .run(&trace)
            .unwrap();
        assert_eq!(report.bytes, bytes, "x{channels}");
        assert_eq!(report.faults.injected_bits, 0, "x{channels}");
        assert_eq!(report.faults.observed_error_bits, 0, "x{channels}");
    }
}

#[test]
fn mram_ber_extremes_are_exact() {
    // The two degenerate bins are analytically pinned: reliable is
    // bit-identical to the perfect channel, and saturated (BER 1.0,
    // polarity 0.5) is a deterministic full inversion — every data bit
    // of every resilient transfer flips, so an 0xA5 stream comes back
    // 0x5A with exactly 64 injected bits per word.
    let n = 100 * 64;
    let bytes = vec![0xA5u8; n];
    let trace = Trace::from_bytes(bytes.clone());
    let spec = CodecSpec::named("ORG");

    let clean = run(&spec, FaultSpec::mram(MramBin::Reliable), Execution::Batch, 1, &trace);
    assert_eq!(clean.bytes, bytes, "reliable bin corrupted the stream");
    assert_eq!(clean.faults.injected_bits, 0);

    let sat = run(&spec, FaultSpec::mram(MramBin::Saturated), Execution::Batch, 1, &trace);
    assert_eq!(sat.faults.injected_bits, (n as u64 / 8) * 64);
    assert!(sat.bytes.iter().all(|&b| b == 0x5A), "saturated bin is not a full inversion");
    // Deterministic, so a second run is byte-identical.
    let again = run(&spec, FaultSpec::mram(MramBin::Saturated), Execution::Batch, 1, &trace);
    assert_eq!(sat.bytes, again.bytes);
}

#[test]
fn mram_polarity_is_the_mirror_of_dram_charge_loss() {
    // Read disturb dominates MRAM retention loss: only a quarter of
    // flips are 1->0, so an all-ones stream must see roughly 3x *fewer*
    // flips than an all-zero stream — the inverse of the DRAM ratio
    // pinned above.
    let n = 64 * 1024;
    let faults = FaultSpec::mram(MramBin::Aggressive).with_seed(19);
    let ones = run(
        &CodecSpec::named("ORG"),
        faults,
        Execution::Batch,
        1,
        &Trace::from_bytes(vec![0xFF; n]),
    );
    let zeros = run(
        &CodecSpec::named("ORG"),
        faults,
        Execution::Batch,
        1,
        &Trace::from_bytes(vec![0x01; n]), // sparse, never zero-skipped
    );
    assert!(ones.faults.injected_bits > 0);
    assert!(zeros.faults.injected_bits > 0);
    let ratio = ones.faults.injected_bits as f64 / zeros.faults.injected_bits as f64;
    // All-ones has 8x the exposed 1-bits of the 0x01 stream, so the
    // expected ratio is 8 * (p_one / (7 p_zero + p_one)) with
    // p_one/p_zero = 1/3: about 8 * (1/22) * ... keep it simple and
    // compare per-polarity rates directly: flips-per-exposed-bit.
    let ones_rate = ones.faults.injected_bits as f64 / (n as f64 * 8.0);
    let zeros_rate = zeros.faults.injected_bits as f64 / (n as f64 * 7.0); // 0-bits per 0x01 byte
    let polarity = ones_rate / zeros_rate;
    assert!(
        (0.2..0.5).contains(&polarity),
        "1->0 / 0->1 per-bit ratio {polarity} far from the 1/3 read-disturb bias (raw ratio {ratio})"
    );
}

#[test]
fn all_critical_traffic_sees_no_mram_injection() {
    // The hardened-traffic contract holds for the second technology
    // too, including at the absurd-BER bin.
    let bytes = image_like(80 * 64, 63);
    let trace = Trace::from_bytes(bytes.clone());
    for bin in [MramBin::Weak, MramBin::Saturated] {
        let report = Session::builder()
            .codec(CodecSpec::zac(80))
            .traffic(TrafficClass::Critical)
            .faults(FaultSpec::mram(bin))
            .build()
            .unwrap()
            .run(&trace)
            .unwrap();
        assert_eq!(report.bytes, bytes, "{bin:?}");
        assert_eq!(report.faults.injected_bits, 0, "{bin:?}");
    }
}

#[test]
fn mram_injection_is_reproducible_and_shard_decorrelated() {
    // Same acceptance as the DRAM path: fixed-seed runs are
    // byte-identical at every channel count, and resharding the array
    // re-derives per-(shard, chip) seeds, so the corruption pattern
    // legitimately differs across channel counts while each stays
    // internally deterministic.
    let bytes = image_like(200 * 64, 65);
    let trace = Trace::from_bytes(bytes.clone());
    let faults = FaultSpec::mram(MramBin::Scaled).with_seed(23);
    let mut streams = Vec::new();
    for channels in [1usize, 2, 4] {
        let a = run(&CodecSpec::named("BDE"), faults, Execution::Sharded, channels, &trace);
        let b = run(&CodecSpec::named("BDE"), faults, Execution::Sharded, channels, &trace);
        assert_eq!(a.bytes, b.bytes, "x{channels}: not reproducible");
        assert_eq!(a.faults, b.faults, "x{channels}");
        assert!(a.faults.injected_bits > 0, "x{channels}");
        streams.push(a.bytes);
    }
    assert_ne!(streams[0], streams[1], "x1 and x2 shards share a fault stream");
    assert_ne!(streams[1], streams[2], "x2 and x4 shards share a fault stream");
}

#[test]
fn secded_repairs_weak_mram_where_the_bare_scheme_cannot() {
    // End-to-end correction accounting: under the weak bin's 1e-4 BER
    // nearly every corrupted beat holds a single flip, so SECDED must
    // repair almost everything while bare ORG keeps every error — the
    // session-level view of the sweep acceptance criterion.
    let bytes = image_like(400 * 64, 67);
    let trace = Trace::from_bytes(bytes);
    let faults = FaultSpec::mram(MramBin::Weak).with_seed(29);
    let bare = run(&CodecSpec::named("ORG"), faults, Execution::Batch, 1, &trace);
    let ecc = run(&CodecSpec::named("SECDED"), faults, Execution::Batch, 1, &trace);
    assert!(bare.faults.injected_bits > 0);
    assert_eq!(bare.faults.corrected_bits, 0);
    assert_eq!(bare.faults.residual_error_bits, bare.faults.observed_error_bits);
    assert!(ecc.faults.corrected_bits > 0, "SECDED never repaired a bit");
    assert!(
        ecc.faults.residual_error_bits < bare.faults.residual_error_bits,
        "correction did not shrink the residual: {} vs {}",
        ecc.faults.residual_error_bits,
        bare.faults.residual_error_bits
    );
}

#[test]
fn faulty_zac_stays_decodable_and_bounded_under_heavy_injection() {
    // Corrupted one-hot indices and xor payloads must decode to *some*
    // word (total decoders, no panics) even at absurd BERs, and the
    // stream shape survives: same length, deterministic result.
    let bytes = image_like(200 * 64, 61);
    let trace = Trace::from_bytes(bytes.clone());
    for spec in spec_matrix() {
        let report = run(
            &spec,
            FaultSpec::uniform(0.05).with_seed(17),
            Execution::Sharded,
            2,
            &trace,
        );
        assert_eq!(report.bytes.len(), bytes.len(), "{}", spec.label());
        assert!(report.faults.injected_bits > 0, "{}", spec.label());
    }
}
