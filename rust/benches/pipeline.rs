//! End-to-end pipeline benches: streaming (bounded queues) vs batch
//! coordination, the sharded channel array at 1/2/4 channels, plus the
//! PJRT inference path (requires artifacts).

use zac_dest::coordinator::Pipeline;
use zac_dest::encoding::{CodecSpec, ZacConfig};
use zac_dest::runtime::{pack_words_i32, Runtime, Tensor};
use zac_dest::session::{Execution, Session, Trace, TrafficClass};
use zac_dest::trace::bytes_to_chip_words;
use zac_dest::util::bench::Bencher;
use zac_dest::util::rng::seeded_rng;

fn main() {
    let mut b = Bencher::new();
    let mut r = seeded_rng(9);
    let mut v = 100i32;
    let bytes: Vec<u8> = (0..1 << 19)
        .map(|_| {
            v = (v + (r.below(9) as i32 - 4)).clamp(0, 255);
            v as u8
        })
        .collect();
    let cfg = ZacConfig::zac(80);
    let spec = CodecSpec::zac(80);
    let trace = Trace::from_bytes(bytes.clone());

    let batch = Session::builder()
        .codec(spec.clone())
        .traffic(TrafficClass::Approximate)
        .build()
        .expect("batch session");
    b.bench_with_units("batch_512KiB", bytes.len() as u64, "B", || {
        batch.run(&trace).expect("batch run")
    });

    // Legacy streaming pipeline (kept as the shim-coverage bench).
    let lines = bytes_to_chip_words(&bytes);
    b.bench_with_units("streaming_512KiB_cap64", bytes.len() as u64, "B", || {
        let mut p = Pipeline::new(&cfg, 64);
        for l in &lines {
            p.push_line(*l, true);
        }
        p.finish(bytes.len())
    });

    // Multi-channel system layer: round-robin interleave across 1/2/4
    // independent 8-chip channels, one service-loop worker each, via
    // the sharded Session path (zero-copy LineChunk views of the trace).
    for shards in [1usize, 2, 4] {
        let session = Session::builder()
            .codec(spec.clone())
            .channels(shards)
            .execution(Execution::Sharded)
            .traffic(TrafficClass::Approximate)
            .build()
            .expect("sharded session");
        b.bench_with_units(
            &format!("channel_array_512KiB_x{shards}"),
            bytes.len() as u64,
            "B",
            || session.run(&trace).expect("sharded run"),
        );
    }

    // Zero-copy bulk ingestion vs per-line streaming: the same
    // 2-channel array fed by indexed views of the shared trace store
    // (push_store, what Session ships) against the copying push_line
    // path (the v1-shaped streaming interface; its chunks are also
    // LineChunks now, so this isolates the ingestion copies, not the
    // whole refactor).
    {
        use zac_dest::system::{AddressSpec, ChannelArray};
        let session = Session::builder()
            .codec(spec.clone())
            .channels(2)
            .execution(Execution::Sharded)
            .traffic(TrafficClass::Approximate)
            .build()
            .expect("sharded session");
        b.bench_with_units(
            "channel_array_512KiB_x2_zero_copy",
            bytes.len() as u64,
            "B",
            || session.run(&trace).expect("zero-copy run"),
        );
        b.bench_with_units(
            "channel_array_512KiB_x2_push_line_copy",
            bytes.len() as u64,
            "B",
            || {
                let mut a = ChannelArray::new(&cfg, 2, 1024);
                for l in trace.lines() {
                    a.push_line(*l, true);
                }
                a.finish(trace.byte_len())
            },
        );
        // Locality steering at the same shard count: the DataTable
        // hit-rate win has a throughput cost/benefit worth tracking.
        let steer = Session::builder()
            .codec(spec.clone())
            .channels(2)
            .address(AddressSpec::steer())
            .execution(Execution::Sharded)
            .traffic(TrafficClass::Approximate)
            .build()
            .expect("steered session");
        b.bench_with_units(
            "channel_array_512KiB_x2_steer",
            bytes.len() as u64,
            "B",
            || steer.run(&trace).expect("steered run"),
        );
    }

    // PJRT path: bulk trace analytics + CNN inference per batch.
    match Runtime::load(Runtime::default_dir()) {
        Ok(rt) => {
            let words: Vec<u64> = (0..8192).map(|_| r.next_u64()).collect();
            let t = Tensor::i32(pack_words_i32(&words), &[8192, 2]);
            rt.precompile(&["trace_stats"]).unwrap();
            b.bench_with_units("pjrt_trace_stats_8192w", 8192, "word", || {
                rt.exec("trace_stats", &[t.clone()]).unwrap()
            });
            if rt.precompile(&["cnn_infer"]).is_ok() {
                let imgs = Tensor::f32(vec![0.5; 32 * 32 * 32 * 3], &[32, 32, 32, 3]);
                let params = zac_dest::workloads::cnn::CnnParams::init(1);
                let mut args = vec![imgs];
                args.extend(params.0.iter().cloned());
                b.bench_with_units("pjrt_cnn_infer_batch32", 32, "img", || {
                    rt.exec("cnn_infer", &args).unwrap()
                });
            }
        }
        Err(e) => eprintln!("skipping PJRT benches (run `make artifacts`): {e}"),
    }
    b.write_json("BENCH_pipeline.json").expect("write BENCH_pipeline.json");
}
