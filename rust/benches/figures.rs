//! Figure regeneration benches: time to recompute the energy series
//! behind each paper table/figure (quality figures need the trained
//! suite and are exercised by `zac-dest figures`, not here).

use zac_dest::figures::{self, FigureCtx};
use zac_dest::util::bench::Bencher;
use zac_dest::workloads::SuiteBudget;

fn main() {
    let mut b = Bencher::new();
    let ctx = FigureCtx::new(42, SuiteBudget::quick());
    for id in ["fig1", "fig2", "fig10", "fig14", "fig19", "fig22", "table1"] {
        b.bench(&format!("render/{id}"), || figures::render(&ctx, id).unwrap());
    }
    // The §VI circuit activity run, at reduced vector count.
    b.bench("circuits/evaluate_1k_vectors", || {
        zac_dest::circuits::evaluate(1000, 42)
    });
}
