//! Scalar-vs-SIMD CAM kernel comparison: the same 64-entry search hot
//! path as `table_search`, but pinned per backend so the dispatched
//! kernel's speedup over the portable scalar reference is a committed
//! artifact. Results merge into `BENCH_encoder.json` (alongside the
//! encoder-throughput rows) rather than a separate report, so one file
//! carries the whole encoder perf trajectory across PRs.

use zac_dest::encoding::{simd, DataTable};
use zac_dest::util::bench::Bencher;
use zac_dest::util::rng::seeded_rng;

fn main() {
    let mut b = Bencher::new();
    let mut r = seeded_rng(7);
    let queries: Vec<u64> = (0..4096).map(|_| r.next_u64()).collect();
    let dispatched = simd::default_backend().expect("resolve default SIMD backend");
    println!(
        "dispatched backend: {} (available: {})",
        dispatched.label(),
        simd::available_backends()
            .iter()
            .map(|k| k.label())
            .collect::<Vec<_>>()
            .join(", ")
    );
    for backend in simd::available_backends() {
        let label = backend.label();
        let mut table = DataTable::with_backend(64, backend);
        for q in queries.iter().take(64) {
            table.push(q ^ 0x5A5A_5A5A_5A5A_5A5A);
        }
        let mut i = 0;
        b.bench_with_units(
            &format!("simd_compare/most_similar/{label}/table64"),
            1,
            "search",
            || {
                i = (i + 1) & 4095;
                table.most_similar_sliced(queries[i])
            },
        );
        let mut hits = Vec::with_capacity(queries.len());
        b.bench_with_units(
            &format!("simd_compare/most_similar_batch/{label}/table64_x4096"),
            queries.len() as u64,
            "search",
            || {
                table.most_similar_batch(&queries, &mut hits);
                hits.len()
            },
        );
        // Worst-case membership probe: misses scan the full table.
        let mut i = 0;
        b.bench_with_units(
            &format!("simd_compare/contains_miss/{label}/table64"),
            1,
            "probe",
            || {
                i = (i + 1) & 4095;
                table.contains(queries[i])
            },
        );
    }
    b.merge_json("BENCH_encoder.json").expect("merge into BENCH_encoder.json");
}
