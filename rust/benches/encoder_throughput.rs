//! Encoder throughput per scheme (the cost side of every paper table):
//! bytes/s through the full 8-chip encode → wire → decode path, driven
//! through the v2 `Session` API.
//!
//! `ZAC_BENCH_BYTES` overrides the input size (default 1 MiB; CI smoke
//! runs 64 KiB). Results are printed and persisted to
//! `BENCH_encoder.json` so the perf trajectory is tracked across PRs.

use zac_dest::encoding::{CodecSpec, Scheme};
use zac_dest::session::{Session, Trace, TrafficClass};
use zac_dest::system::bench_bytes_from_env;
use zac_dest::system::synthetic_trace as image_like;
use zac_dest::util::bench::Bencher;

fn size_label(n: usize) -> String {
    if n >= (1 << 20) && n % (1 << 20) == 0 {
        format!("{}MiB", n >> 20)
    } else if n >= (1 << 10) {
        format!("{}KiB", n >> 10)
    } else {
        format!("{n}B")
    }
}

fn bench_spec(b: &mut Bencher, name: &str, spec: CodecSpec, trace: &Trace) {
    let session = Session::builder()
        .codec(spec)
        .traffic(TrafficClass::Approximate)
        .build()
        .expect("valid bench spec");
    b.bench_with_units(name, trace.byte_len() as u64, "B", || {
        session.run(trace).expect("bench run")
    });
}

fn main() {
    let mut b = Bencher::new();
    let n: usize = bench_bytes_from_env()
        .expect("ZAC_BENCH_BYTES")
        .unwrap_or(1 << 20);
    let trace = Trace::from_bytes(image_like(n, 42));
    let sz = size_label(n);
    for scheme in Scheme::all() {
        bench_spec(
            &mut b,
            &format!("simulate_{sz}/{}", scheme.label()),
            CodecSpec::named(scheme.label()),
            &trace,
        );
    }
    for limit in [90u32, 80, 70] {
        bench_spec(
            &mut b,
            &format!("simulate_{sz}/ZAC_L{limit}"),
            CodecSpec::zac(limit),
            &trace,
        );
    }
    // Knobbed variant (truncation+tolerance active).
    bench_spec(
        &mut b,
        &format!("simulate_{sz}/ZAC_L75_T16_O8"),
        CodecSpec::zac_full(75, 2, 1),
        &trace,
    );
    b.write_json("BENCH_encoder.json").expect("write BENCH_encoder.json");
}
