//! Encoder throughput per scheme (the cost side of every paper table):
//! bytes/s through the full 8-chip encode → wire → decode path.
//!
//! `ZAC_BENCH_BYTES` overrides the input size (default 1 MiB; CI smoke
//! runs 64 KiB). Results are printed and persisted to
//! `BENCH_encoder.json` so the perf trajectory is tracked across PRs.

use zac_dest::coordinator::simulate_bytes;
use zac_dest::encoding::{Scheme, ZacConfig};
use zac_dest::util::bench::Bencher;
use zac_dest::util::rng::Rng;

fn image_like(n: usize, seed: u64) -> Vec<u8> {
    let mut r = Rng::new(seed);
    let mut v = 128i32;
    (0..n)
        .map(|_| {
            v = (v + (r.below(9) as i32 - 4)).clamp(0, 255);
            v as u8
        })
        .collect()
}

fn size_label(n: usize) -> String {
    if n >= (1 << 20) && n % (1 << 20) == 0 {
        format!("{}MiB", n >> 20)
    } else if n >= (1 << 10) {
        format!("{}KiB", n >> 10)
    } else {
        format!("{n}B")
    }
}

fn main() {
    let mut b = Bencher::new();
    let n: usize = std::env::var("ZAC_BENCH_BYTES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 20);
    let bytes = image_like(n, 42);
    let sz = size_label(n);
    for scheme in Scheme::all() {
        let cfg = ZacConfig::scheme(scheme);
        b.bench_with_units(
            &format!("simulate_{sz}/{}", scheme.label()),
            bytes.len() as u64,
            "B",
            || simulate_bytes(&cfg, &bytes, true),
        );
    }
    for limit in [90u32, 80, 70] {
        let cfg = ZacConfig::zac(limit);
        b.bench_with_units(
            &format!("simulate_{sz}/ZAC_L{limit}"),
            bytes.len() as u64,
            "B",
            || simulate_bytes(&cfg, &bytes, true),
        );
    }
    // Knobbed variant (truncation+tolerance active).
    let cfg = ZacConfig::zac_full(75, 2, 1);
    b.bench_with_units(
        &format!("simulate_{sz}/ZAC_L75_T16_O8"),
        bytes.len() as u64,
        "B",
        || simulate_bytes(&cfg, &bytes, true),
    );
    b.write_json("BENCH_encoder.json").expect("write BENCH_encoder.json");
}
