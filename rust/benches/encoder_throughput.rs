//! Encoder throughput per scheme (the cost side of every paper table):
//! bytes/s through the full 8-chip encode → wire → decode path.

use zac_dest::coordinator::simulate_bytes;
use zac_dest::encoding::{Scheme, ZacConfig};
use zac_dest::util::bench::Bencher;
use zac_dest::util::rng::Rng;

fn image_like(n: usize, seed: u64) -> Vec<u8> {
    let mut r = Rng::new(seed);
    let mut v = 128i32;
    (0..n)
        .map(|_| {
            v = (v + (r.below(9) as i32 - 4)).clamp(0, 255);
            v as u8
        })
        .collect()
}

fn main() {
    let mut b = Bencher::new();
    let bytes = image_like(1 << 20, 42);
    for scheme in Scheme::all() {
        let cfg = ZacConfig::scheme(scheme);
        b.bench_with_units(
            &format!("simulate_1MiB/{}", scheme.label()),
            bytes.len() as u64,
            "B",
            || simulate_bytes(&cfg, &bytes, true),
        );
    }
    for limit in [90u32, 80, 70] {
        let cfg = ZacConfig::zac(limit);
        b.bench_with_units(
            &format!("simulate_1MiB/ZAC_L{limit}"),
            bytes.len() as u64,
            "B",
            || simulate_bytes(&cfg, &bytes, true),
        );
    }
    // Knobbed variant (truncation+tolerance active).
    let cfg = ZacConfig::zac_full(75, 2, 1);
    b.bench_with_units("simulate_1MiB/ZAC_L75_T16_O8", bytes.len() as u64, "B", || {
        simulate_bytes(&cfg, &bytes, true)
    });
}
