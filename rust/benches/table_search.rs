//! CAM-search microbenchmark: the L3 hot path (64-entry XOR+popcount
//! argmin per word per chip). Compares table sizes as in [14]'s table
//! sweep discussion (§VIII-A).

use zac_dest::channel::ChipChannel;
use zac_dest::encoding::{
    CodecRegistry, CodecSpec, DataTable, EncodeStats, WireWord, ENCODE_BATCH,
};
use zac_dest::util::bench::Bencher;
use zac_dest::util::rng::seeded_rng;

fn main() {
    let mut b = Bencher::new();
    let mut r = seeded_rng(7);
    let queries: Vec<u64> = (0..4096).map(|_| r.next_u64()).collect();
    for size in [16usize, 32, 64] {
        let mut table = DataTable::new(size);
        for _ in 0..size {
            table.push(r.next_u64());
        }
        let mut i = 0;
        b.bench_with_units(&format!("most_similar/table{size}"), 1, "search", || {
            i = (i + 1) & 4095;
            table.most_similar(queries[i])
        });
        let mut i = 0;
        b.bench_with_units(&format!("most_similar_sliced/table{size}"), 1, "search", || {
            i = (i + 1) & 4095;
            table.most_similar_sliced(queries[i])
        });
        let mut hits = Vec::with_capacity(queries.len());
        b.bench_with_units(
            &format!("most_similar_batch/table{size}_x4096"),
            queries.len() as u64,
            "search",
            || {
                table.most_similar_batch(&queries, &mut hits);
                hits.len()
            },
        );
    }
    // Early-exit case: query present in the table.
    let mut table = DataTable::new(64);
    for q in queries.iter().take(64) {
        table.push(*q);
    }
    let mut i = 0;
    b.bench_with_units("most_similar/exact_hit", 1, "search", || {
        i = (i + 1) & 63;
        table.most_similar(queries[i])
    });
    // Full encode+decode step per word, through a registry-built codec.
    let registry = CodecRegistry::with_builtins();
    let spec = CodecSpec::zac(80);
    let mut codec = registry.build(&spec).expect("builtin codec");
    let mut chan = ChipChannel::new();
    let mut stats = EncodeStats::default();
    let mut i = 0;
    b.bench_with_units("encode_decode_word/ZAC_L80", 1, "word", || {
        i = (i + 1) & 4095;
        let wire = codec.encoder.encode(queries[i], true);
        chan.transmit(&wire);
        stats.record(&wire, queries[i]);
        codec.decoder.decode(&wire)
    });
    // Same step through the batch hot path.
    let mut codec = registry.build(&spec).expect("builtin codec");
    let mut chan = ChipChannel::new();
    let mut stats = EncodeStats::default();
    let mut wires = [WireWord::raw(0); ENCODE_BATCH];
    let flags = [true; ENCODE_BATCH];
    let mut decoded: Vec<u64> = Vec::with_capacity(ENCODE_BATCH);
    let mut base = 0usize;
    b.bench_with_units("encode_decode_batch256/ZAC_L80", ENCODE_BATCH as u64, "word", || {
        base = (base + ENCODE_BATCH) & 4095;
        let words = &queries[base..base + ENCODE_BATCH];
        codec.encoder.encode_batch(words, &flags, &mut wires);
        chan.transmit_batch(&wires);
        stats.record_batch(&wires, words);
        decoded.clear();
        codec.decoder.decode_batch(&wires, &mut decoded);
        decoded.len()
    });
    b.write_json("BENCH_table_search.json").expect("write BENCH_table_search.json");
}
