//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The sandbox has no `xla_extension` shared library, so this crate
//! supplies the exact API surface `zac_dest::runtime` compiles against
//! while failing cleanly at *runtime*: [`PjRtClient::cpu`] returns an
//! error, which every caller already handles (the benches and examples
//! print "skipping PJRT" and the runtime tests require `make artifacts`
//! anyway). Deploy against the real bindings by deleting the `path`
//! override in the workspace manifest.

use std::fmt;

/// Stub error carrying a static explanation.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable (stub xla crate; link the real xla_extension to enable)"
    )))
}

/// Element types the runtime moves across the PJRT boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side literal (stub: carries no data).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("Literal::to_literal_sync")
    }
}

/// Parsed HLO module handle (stub: empty).
#[derive(Debug, Default)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper accepted by [`PjRtClient::compile`].
#[derive(Debug, Default)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle. The stub cannot construct one, which is the
/// single runtime gate keeping all downstream methods unreachable.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle (stub: never constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<Literal>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_stub() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("stub"), "{err}");
    }

    #[test]
    fn literal_surface_compiles() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }
}
