//! Minimal vendored stand-in for the `anyhow` crate, covering exactly the
//! API surface this workspace uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros and the [`Context`]
//! extension trait. The sandbox builds fully offline, so the real crate
//! cannot be fetched from a registry; this drop-in keeps `?`-conversion
//! from any `std::error::Error` and the context-chain `Display` the
//! callers rely on. Swap back to the real `anyhow` by deleting the
//! `path` override in the workspace manifest.

use std::error::Error as StdError;
use std::fmt;

/// Error: a root message plus the contexts wrapped around it
/// (outermost last, as added).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    fn wrap(mut self, context: String) -> Error {
        self.chain.push(context);
        self
    }

    /// The outermost context down to the root cause.
    fn render(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, c) in self.chain.iter().rev().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{c}")?;
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.render(f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.render(f)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`: that keeps this blanket conversion (used by `?`)
// coherent with core's reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut source = e.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Error::msg(msg)
    }
}

/// `anyhow::Result<T>` alias with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazy or eager context to a `Result` or `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tok:tt)*) => {
        return Err($crate::anyhow!($($tok)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($tok:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($tok)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse()?; // io-free StdError conversion via `?`
        ensure!(n < 100, "{n} out of range");
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
        assert!(parse("100").unwrap_err().to_string().contains("out of range"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let base: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let e = base.context("opening manifest").unwrap_err();
        let shown = e.to_string();
        assert!(shown.starts_with("opening manifest"), "{shown}");
        assert!(shown.contains("missing"), "{shown}");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("needed a value").unwrap_err();
        assert_eq!(e.to_string(), "needed a value");
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 7;
        let b = anyhow!("inline {x}");
        assert_eq!(b.to_string(), "inline 7");
        let c = anyhow!("args {} {}", 1, 2);
        assert_eq!(c.to_string(), "args 1 2");
        let d = anyhow!(String::from("owned"));
        assert_eq!(d.to_string(), "owned");
    }
}
